//! Mergeable relative-error quantile sketch — the tunable-accuracy
//! successor to the fixed one-power-of-two [`LogHistogram`] bound behind
//! `--bounded-stats`.
//!
//! DDSketch-style design, adapted to this crate's no-`libm` rule: instead
//! of log-γ bucket keys (which need `ln`), each power-of-two octave is
//! split into `m = 2^sub_bits` *linear* sub-buckets by taking the top
//! `sub_bits` mantissa bits straight off the IEEE-754 representation:
//!
//! ```text
//! key(v) = to_bits(v) >> (52 - sub_bits)        // positive finite v
//! ```
//!
//! Positive doubles order exactly like their bit patterns, so the key is
//! monotone and integer-exact — merging two sketches is bucket-wise `u64`
//! addition, which is associative and commutative, making merge-then-
//! quantile *identical* (not just close) to concat-then-quantile. The
//! relative width of one sub-bucket is at most `1/m`, so every quantile
//! estimate is within relative error `ε = 2^-sub_bits` of the true
//! (nearest-rank) sample for normal floats. (Subnormals — latencies below
//! ~1e-308 cycles — degrade toward one shared bucket; irrelevant at this
//! crate's scales, noted for honesty.)
//!
//! `--quantile-error EPS` selects the smallest `sub_bits` whose `1/2^k`
//! is ≤ EPS; `EPS ≥ 1.0` degenerates to `sub_bits = 0`, which is exactly
//! the octave bucketing of [`LogHistogram`] (pinned by a unit test).
//!
//! Memory stays bounded by collapsing the *lowest* non-sentinel bucket
//! into its neighbor once the bucket map exceeds `max_buckets` (DDSketch
//! collapses the low tail for the same reason: high quantiles are the
//! ones that matter). The collapsed count is tracked and surfaced.
//!
//! [`LogHistogram`]: crate::telemetry::metrics::LogHistogram

use std::collections::BTreeMap;

/// Default relative error when `--quantile-error` is not given: 1% maps
/// to `sub_bits = 7` (128 sub-buckets per octave, true error ≤ 1/128).
pub const DEFAULT_QUANTILE_ERROR: f64 = 0.01;

/// Key for values ≤ 0 or NaN (reported as 0.0, like `LogHistogram`'s
/// `i32::MIN` sentinel bucket).
const SENTINEL_LOW: i64 = i64::MIN;
/// Key for +∞ (reported as +∞ — it must not be folded into a finite
/// bucket, or p100 would silently deflate).
const SENTINEL_HIGH: i64 = i64::MAX;

/// Hard ceiling on `sub_bits`: 2^16 sub-buckets per octave (ε ≈ 1.5e-5)
/// is already far below any simulated-latency noise floor.
const MAX_SUB_BITS: u32 = 16;

/// Default bucket-count bound. At `sub_bits = 7` a full double-precision
/// dynamic range is ~2048 octaves × 128 = impossible to fill in practice;
/// real latency distributions span a handful of octaves, so 4096 buckets
/// means collapse effectively never fires outside adversarial tests.
const DEFAULT_MAX_BUCKETS: usize = 4096;

/// Smallest `sub_bits` whose relative error `1/2^k` is ≤ `eps`; non-
/// positive / NaN `eps` falls back to [`DEFAULT_QUANTILE_ERROR`].
fn sub_bits_for(eps: f64) -> u32 {
    let eps = if eps > 0.0 { eps } else { DEFAULT_QUANTILE_ERROR };
    for k in 0..=MAX_SUB_BITS {
        if 1.0 / (1u64 << k) as f64 <= eps {
            return k;
        }
    }
    MAX_SUB_BITS
}

/// A mergeable quantile sketch with bounded memory and a tunable
/// relative-error guarantee (see the module docs for the construction).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    sub_bits: u32,
    /// Sparse bucket counts keyed by the monotone mantissa-prefix key.
    buckets: BTreeMap<i64, u64>,
    count: u64,
    sum: f64,
    /// Running maximum (`NEG_INFINITY` when empty — the identity under
    /// `f64::max`, so merges need no empty-case branch).
    max: f64,
    /// Samples folded out of collapsed low-tail buckets (their count is
    /// retained, their position degraded upward by one bucket at a time).
    collapsed: u64,
    max_buckets: usize,
}

impl QuantileSketch {
    /// A sketch with relative error ≤ `eps` and the default memory bound.
    pub fn new(eps: f64) -> Self {
        Self::with_bound(eps, DEFAULT_MAX_BUCKETS)
    }

    /// A sketch with an explicit bucket-count bound (tests use tiny
    /// bounds to exercise the collapse path).
    pub fn with_bound(eps: f64, max_buckets: usize) -> Self {
        assert!(max_buckets >= 2, "a sketch needs at least two buckets");
        QuantileSketch {
            sub_bits: sub_bits_for(eps),
            buckets: BTreeMap::new(),
            count: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
            collapsed: 0,
            max_buckets,
        }
    }

    fn key(&self, v: f64) -> i64 {
        if !(v > 0.0) {
            SENTINEL_LOW
        } else if v.is_infinite() {
            SENTINEL_HIGH
        } else {
            (v.to_bits() >> (52 - self.sub_bits)) as i64
        }
    }

    /// Lower edge of bucket `k` (inverse of [`Self::key`]).
    fn bucket_lo(&self, k: i64) -> f64 {
        f64::from_bits((k as u64) << (52 - self.sub_bits))
    }

    /// Upper edge of bucket `k`, clamped to finite.
    fn bucket_hi(&self, k: i64) -> f64 {
        let hi = f64::from_bits(((k as u64) + 1) << (52 - self.sub_bits));
        if hi.is_finite() {
            hi
        } else {
            f64::MAX
        }
    }

    /// Record one sample.
    pub fn record(&mut self, v: f64) {
        let k = self.key(v);
        *self.buckets.entry(k).or_insert(0) += 1;
        self.count += 1;
        self.sum += v;
        self.max = self.max.max(v);
        if self.buckets.len() > self.max_buckets {
            self.enforce_bound();
        }
    }

    /// Merge `other` into `self` (bucket-wise integer addition — exact,
    /// associative, and commutative, so merge order cannot change any
    /// quantile). Both sketches must share a resolution.
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert_eq!(
            self.sub_bits, other.sub_bits,
            "merging sketches with different --quantile-error resolutions"
        );
        for (&k, &c) in &other.buckets {
            *self.buckets.entry(k).or_insert(0) += c;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.collapsed += other.collapsed;
        self.max = self.max.max(other.max);
        if self.buckets.len() > self.max_buckets {
            self.enforce_bound();
        }
    }

    /// Collapse lowest non-sentinel buckets upward until the bound holds.
    fn enforce_bound(&mut self) {
        while self.buckets.len() > self.max_buckets {
            let mut low = self.buckets.keys().copied().filter(|&k| k != SENTINEL_LOW && k != SENTINEL_HIGH);
            let (Some(lowest), Some(next)) = (low.next(), low.next()) else {
                return; // nothing left to fold
            };
            let c = self.buckets.remove(&lowest).expect("lowest bucket exists");
            *self.buckets.entry(next).or_insert(0) += c;
            self.collapsed += c;
        }
    }

    /// Nearest-rank quantile estimate for percentile `p` in `[0, 100]`
    /// (`NaN` when empty) — the exact same rank rule as the exact path
    /// and `LogHistogram`, with linear interpolation inside the bucket.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile {p} out of [0, 100]");
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (((p / 100.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut before = 0u64;
        for (&k, &c) in &self.buckets {
            if before + c >= rank {
                if k == SENTINEL_LOW {
                    return 0.0;
                }
                if k == SENTINEL_HIGH {
                    return f64::INFINITY;
                }
                let lo = self.bucket_lo(k);
                let hi = self.bucket_hi(k);
                let frac = (rank - before) as f64 / c as f64;
                return lo + frac * (hi - lo);
            }
            before += c;
        }
        f64::NAN
    }

    /// The guaranteed relative error bound `1/2^sub_bits`.
    pub fn relative_error(&self) -> f64 {
        1.0 / (1u64 << self.sub_bits) as f64
    }

    pub fn sub_bits(&self) -> u32 {
        self.sub_bits
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact running maximum (`NaN` when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Exact mean (`NaN` when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Samples whose bucket was collapsed into a neighbor.
    pub fn collapsed(&self) -> u64 {
        self.collapsed
    }

    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The raw `(key, count)` buckets in ascending key order — sentinels
    /// included. This is the sketch's exact serialized form: rebuilding
    /// via [`QuantileSketch::from_parts`] from these pairs reproduces
    /// every quantile bit for bit (the artifact export relies on that).
    pub fn buckets(&self) -> impl Iterator<Item = (i64, u64)> + '_ {
        self.buckets.iter().map(|(&k, &c)| (k, c))
    }

    /// Count of non-positive / NaN samples (the low sentinel bucket).
    pub fn zero_count(&self) -> u64 {
        self.buckets.get(&SENTINEL_LOW).copied().unwrap_or(0)
    }

    /// Count of +∞ samples (the high sentinel bucket).
    pub fn inf_count(&self) -> u64 {
        self.buckets.get(&SENTINEL_HIGH).copied().unwrap_or(0)
    }

    /// Rebuild a sketch from serialized parts: the resolution, the
    /// finite `(key, count)` buckets, the sentinel counts, and the exact
    /// `sum`/`max` moments. `count` is re-derived from the buckets, so a
    /// round-trip through an artifact cannot desynchronize it. The
    /// sentinel keys themselves (`i64::MIN`/`MAX`) never cross the
    /// artifact boundary — they are not exactly representable as JSON
    /// doubles — which is why they travel as separate counts.
    pub fn from_parts(
        sub_bits: u32,
        finite_buckets: impl IntoIterator<Item = (i64, u64)>,
        zero: u64,
        inf: u64,
        sum: f64,
        max: f64,
    ) -> Self {
        let mut buckets: BTreeMap<i64, u64> = BTreeMap::new();
        if zero > 0 {
            buckets.insert(SENTINEL_LOW, zero);
        }
        if inf > 0 {
            buckets.insert(SENTINEL_HIGH, inf);
        }
        for (k, c) in finite_buckets {
            debug_assert!(k != SENTINEL_LOW && k != SENTINEL_HIGH, "sentinels travel separately");
            *buckets.entry(k).or_insert(0) += c;
        }
        let count: u64 = buckets.values().sum();
        QuantileSketch {
            sub_bits: sub_bits.min(MAX_SUB_BITS),
            buckets,
            count,
            sum,
            max: if count == 0 { f64::NEG_INFINITY } else { max },
            collapsed: 0,
            max_buckets: DEFAULT_MAX_BUCKETS,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::metrics::LogHistogram;
    use crate::testutil::Rng;

    #[test]
    fn eps_selects_the_smallest_sufficient_resolution() {
        assert_eq!(sub_bits_for(1.0), 0);
        assert_eq!(sub_bits_for(2.0), 0);
        assert_eq!(sub_bits_for(0.5), 1);
        assert_eq!(sub_bits_for(0.25), 2);
        assert_eq!(sub_bits_for(0.01), 7);
        assert_eq!(sub_bits_for(0.001), 10);
        // Defensive fallbacks and the hard clamp.
        assert_eq!(sub_bits_for(0.0), 7);
        assert_eq!(sub_bits_for(-1.0), 7);
        assert_eq!(sub_bits_for(f64::NAN), 7);
        assert_eq!(sub_bits_for(1.0 / (1u64 << 20) as f64), MAX_SUB_BITS);
    }

    fn seeded_values(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let u = rng.next_f32() as f64;
                // Heavy-tailed mix spanning several octaves.
                0.001 + u * u * 5000.0
            })
            .collect()
    }

    fn exact_quantile(sorted: &[f64], p: f64) -> f64 {
        let n = sorted.len() as u64;
        let rank = (((p / 100.0) * n as f64).ceil() as u64).clamp(1, n);
        sorted[(rank - 1) as usize]
    }

    #[test]
    fn quantiles_stay_within_the_relative_error_bound() {
        for &eps in &[0.25, 0.05, 0.01, 0.001] {
            for seed in 0..4u64 {
                let values = seeded_values(seed * 31 + 1, 3000);
                let mut sk = QuantileSketch::new(eps);
                for &v in &values {
                    sk.record(v);
                }
                let mut sorted = values.clone();
                sorted.sort_by(|a, b| a.total_cmp(b));
                for &p in &[1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
                    let exact = exact_quantile(&sorted, p);
                    let est = sk.quantile(p);
                    let bound = sk.relative_error();
                    assert!(bound <= eps, "resolution looser than requested");
                    let rel = (est - exact).abs() / exact;
                    assert!(
                        rel <= bound + 1e-12,
                        "eps={eps} seed={seed} p={p}: est {est} vs exact {exact} (rel {rel} > {bound})"
                    );
                }
            }
        }
    }

    #[test]
    fn merge_then_quantile_equals_concat_then_quantile() {
        let a_vals = seeded_values(5, 700);
        let b_vals = seeded_values(9, 1300);
        let mut merged = QuantileSketch::new(0.01);
        let mut b = QuantileSketch::new(0.01);
        let mut concat = QuantileSketch::new(0.01);
        for &v in &a_vals {
            merged.record(v);
            concat.record(v);
        }
        for &v in &b_vals {
            b.record(v);
            concat.record(v);
        }
        merged.merge(&b);
        assert_eq!(merged.count(), concat.count());
        for &p in &[1.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(
                merged.quantile(p).to_bits(),
                concat.quantile(p).to_bits(),
                "merge-then-quantile must be bit-identical to concat-then-quantile at p{p}"
            );
        }
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        let parts: Vec<QuantileSketch> = (0..3)
            .map(|i| {
                let mut sk = QuantileSketch::new(0.01);
                for v in seeded_values(i * 7 + 2, 400) {
                    sk.record(v);
                }
                sk
            })
            .collect();
        // Commutative, whole-struct: bucket adds are integer-exact and
        // `a.sum + b.sum == b.sum + a.sum` bit-for-bit.
        let mut ab = parts[0].clone();
        ab.merge(&parts[1]);
        let mut ba = parts[1].clone();
        ba.merge(&parts[0]);
        assert_eq!(ab, ba, "merge must be commutative");
        // Associative on every quantile (integer bucket counts — float
        // `sum` association differences never reach the quantiles).
        let mut left = parts[0].clone();
        left.merge(&parts[1]);
        left.merge(&parts[2]);
        let mut bc = parts[1].clone();
        bc.merge(&parts[2]);
        let mut right = parts[0].clone();
        right.merge(&bc);
        assert_eq!(left.count(), right.count());
        for &p in &[1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(left.quantile(p).to_bits(), right.quantile(p).to_bits());
        }
    }

    #[test]
    fn collapse_keeps_the_count_and_the_high_quantiles() {
        let mut sk = QuantileSketch::with_bound(0.01, 4);
        let values = seeded_values(13, 500);
        for &v in &values {
            sk.record(v);
        }
        assert!(sk.bucket_count() <= 4, "bound not enforced");
        assert_eq!(sk.count(), 500, "collapse must not lose samples");
        assert!(sk.collapsed() > 0, "a 4-bucket bound over octaves must collapse");
        // High quantiles live in the retained top buckets: still within ε.
        let mut sorted = values.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let exact = exact_quantile(&sorted, 99.0);
        let est = sk.quantile(99.0);
        assert!((est - exact).abs() / exact <= sk.relative_error() + 1e-12);
    }

    #[test]
    fn sentinels_handle_nonpositive_and_infinite_samples() {
        let mut sk = QuantileSketch::new(0.01);
        sk.record(0.0);
        sk.record(-3.0);
        sk.record(f64::NAN);
        sk.record(5.0);
        sk.record(f64::INFINITY);
        assert_eq!(sk.count(), 5);
        assert_eq!(sk.quantile(10.0), 0.0, "non-positive samples report 0.0");
        assert_eq!(sk.quantile(100.0), f64::INFINITY);
        let mid = sk.quantile(70.0);
        assert!((mid - 5.0).abs() / 5.0 <= sk.relative_error() + 1e-12);
    }

    #[test]
    fn from_parts_round_trips_every_quantile_bit_for_bit() {
        // The artifact export serializes (sub_bits, finite buckets,
        // sentinel counts, sum, max); the report side rebuilds with
        // `from_parts`. Quantiles on the rebuilt sketch must be
        // bit-identical — that is what lets `wienna report` on a metrics
        // artifact match the stats line exactly under --bounded-stats.
        let mut sk = QuantileSketch::new(0.01);
        for v in seeded_values(17, 1500) {
            sk.record(v);
        }
        sk.record(0.0);
        sk.record(f64::INFINITY);
        let finite: Vec<(i64, u64)> = sk
            .buckets()
            .filter(|&(k, _)| k != SENTINEL_LOW && k != SENTINEL_HIGH)
            .collect();
        let rebuilt = QuantileSketch::from_parts(
            sk.sub_bits(),
            finite,
            sk.zero_count(),
            sk.inf_count(),
            sk.sum(),
            sk.max(),
        );
        assert_eq!(rebuilt.count(), sk.count());
        assert_eq!(rebuilt.zero_count(), 1);
        assert_eq!(rebuilt.inf_count(), 1);
        for p in [0.0, 1.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(
                rebuilt.quantile(p).to_bits(),
                sk.quantile(p).to_bits(),
                "rebuilt quantile drifted at p{p}"
            );
        }
        assert_eq!(rebuilt.max().to_bits(), sk.max().to_bits());
        assert_eq!(rebuilt.mean().to_bits(), sk.mean().to_bits());
    }

    #[test]
    fn empty_sketch_reports_nan() {
        let sk = QuantileSketch::new(0.01);
        assert!(sk.is_empty());
        assert!(sk.quantile(50.0).is_nan());
        assert!(sk.mean().is_nan());
        assert!(sk.max().is_nan());
    }

    #[test]
    fn sub_bits_zero_matches_the_log_histogram_octaves() {
        // eps ≥ 1.0 degenerates to one bucket per power of two — exactly
        // the LogHistogram scheme PR 8 shipped. Quantiles must agree to
        // float-association noise.
        let values = seeded_values(21, 2000);
        let mut sk = QuantileSketch::new(1.0);
        let mut hist = LogHistogram::default();
        for &v in &values {
            sk.record(v);
            hist.record(v);
        }
        assert_eq!(sk.sub_bits(), 0);
        for &p in &[1.0, 50.0, 90.0, 99.0, 100.0] {
            let a = sk.quantile(p);
            let b = hist.quantile(p);
            assert!(
                (a - b).abs() <= 1e-9 * b.abs(),
                "p{p}: sketch {a} vs LogHistogram {b}"
            );
        }
    }
}
