//! Deterministic multi-window SLO burn-rate monitoring.
//!
//! The monitor watches each traffic class's cumulative
//! `(completed, slo_violated)` counters as snapshotted **single-threaded
//! at the `cluster::sync` epoch barrier** and raises/clears alerts when
//! the *burn rate* — the observed violation fraction over a trailing
//! window, divided by the error-budget objective — crosses a threshold.
//! Two windows per class (the classic fast/slow pairing): a short
//! window with a high threshold pages quickly on a cliff, a long window
//! with a low threshold catches a slow bleed without flapping.
//!
//! Everything here is deterministic by construction: inputs are the
//! deterministically merged per-class counters, evaluation happens at
//! barrier cycles only, and events carry those exact cycles — so the
//! alert timeline in the metrics artifact is byte-identical at any
//! worker-thread count, like every other telemetry surface.
//!
//! Memory is bounded: the monitor keeps one ring of barrier snapshots
//! per class, pruned past the slow window — O(slow_window /
//! epoch_cycles) regardless of how many requests the run serves.

use std::collections::VecDeque;

use crate::cluster::{TrafficClass, NUM_CLASSES};
use crate::serve::ms_to_cycles;

/// Burn-rate policy knobs, carried by `TelemetryConfig`.
#[derive(Debug, Clone, Copy)]
pub struct SloPolicy {
    /// Error-budget objective: the tolerated SLO-violation fraction.
    /// Burn rate 1.0 means violations arrive exactly at budget.
    pub objective: f64,
    /// Trailing fast-window length, cycles.
    pub fast_window_cycles: f64,
    /// Trailing slow-window length, cycles.
    pub slow_window_cycles: f64,
    /// Raise threshold for the fast window (burn-rate multiple).
    pub fast_burn: f64,
    /// Raise threshold for the slow window (burn-rate multiple).
    pub slow_burn: f64,
    /// Minimum completions inside a window before its alert state may
    /// change — below this the estimate is too noisy to act on.
    pub min_requests: u64,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            objective: 0.05,
            fast_window_cycles: ms_to_cycles(2.0),
            slow_window_cycles: ms_to_cycles(10.0),
            fast_burn: 8.0,
            slow_burn: 2.0,
            min_requests: 10,
        }
    }
}

/// Which trailing window an alert belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloWindow {
    Fast,
    Slow,
}

impl SloWindow {
    pub const ALL: [SloWindow; 2] = [SloWindow::Fast, SloWindow::Slow];

    pub fn label(&self) -> &'static str {
        match self {
            SloWindow::Fast => "fast",
            SloWindow::Slow => "slow",
        }
    }
}

/// Alert transition kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloEventKind {
    Raise,
    Clear,
}

impl SloEventKind {
    pub fn label(&self) -> &'static str {
        match self {
            SloEventKind::Raise => "raise",
            SloEventKind::Clear => "clear",
        }
    }
}

/// One alert transition, stamped with the exact barrier it fired at.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloEvent {
    /// Epoch index of the barrier that evaluated the transition.
    pub epoch: u64,
    /// Exact barrier cycle.
    pub cycle: f64,
    pub class: TrafficClass,
    pub window: SloWindow,
    pub kind: SloEventKind,
    /// Burn rate observed at the transition (multiple of the budget).
    pub burn_rate: f64,
}

/// One barrier snapshot of a class's cumulative counters.
#[derive(Debug, Clone, Copy)]
struct Snapshot {
    cycle: f64,
    completed: u64,
    violated: u64,
}

/// The monitor: per-class snapshot rings plus per-(class, window)
/// alert state. Evaluate with [`SloMonitor::observe`] at each barrier.
#[derive(Debug, Clone)]
pub struct SloMonitor {
    policy: SloPolicy,
    history: [VecDeque<Snapshot>; NUM_CLASSES],
    active: [[bool; 2]; NUM_CLASSES],
}

impl SloMonitor {
    pub fn new(policy: SloPolicy) -> Self {
        SloMonitor { policy, history: Default::default(), active: [[false; 2]; NUM_CLASSES] }
    }

    /// Whether the `(class, window)` alert is currently raised.
    pub fn is_active(&self, class: TrafficClass, window: SloWindow) -> bool {
        self.active[class.index()][window as usize]
    }

    /// Count of currently raised alerts across all classes and windows.
    pub fn active_count(&self) -> u64 {
        self.active.iter().flatten().filter(|&&a| a).count() as u64
    }

    /// Burn rate of `class` over the trailing `window` ending at the
    /// latest observed barrier, or NaN when the window holds fewer than
    /// `min_requests` completions.
    pub fn burn_rate(&self, class: TrafficClass, window: SloWindow) -> f64 {
        let ring = &self.history[class.index()];
        let Some(&cur) = ring.back() else { return f64::NAN };
        let len = match window {
            SloWindow::Fast => self.policy.fast_window_cycles,
            SloWindow::Slow => self.policy.slow_window_cycles,
        };
        let base = Self::baseline(ring, cur.cycle - len);
        let dc = cur.completed - base.completed;
        if dc < self.policy.min_requests.max(1) {
            return f64::NAN;
        }
        let dv = cur.violated - base.violated;
        (dv as f64 / dc as f64) / self.policy.objective
    }

    /// The most recent snapshot at or before `cutoff` — the window
    /// baseline. Before the run is a full window old, the zero origin
    /// stands in, so early epochs are measured against run start.
    fn baseline(ring: &VecDeque<Snapshot>, cutoff: f64) -> Snapshot {
        let mut base = Snapshot { cycle: 0.0, completed: 0, violated: 0 };
        for s in ring {
            if s.cycle <= cutoff {
                base = *s;
            } else {
                break;
            }
        }
        base
    }

    /// Feed one barrier's cumulative per-class counters
    /// (`counts[class.index()] = (completed, slo_violated)`) and return
    /// the alert transitions it triggers, in deterministic
    /// (class priority, fast-before-slow) order.
    pub fn observe(
        &mut self,
        epoch: u64,
        cycle: f64,
        counts: &[(u64, u64); NUM_CLASSES],
    ) -> Vec<SloEvent> {
        let mut events = Vec::new();
        for (ci, class) in TrafficClass::ALL.into_iter().enumerate() {
            let (completed, violated) = counts[ci];
            let ring = &mut self.history[ci];
            ring.push_back(Snapshot { cycle, completed, violated });
            // Prune: drop the front while the *next* entry can still
            // serve as the slow-window baseline. Bounds the ring to
            // O(slow_window / epoch_cycles).
            let cutoff = cycle - self.policy.slow_window_cycles;
            while ring.len() > 1 && ring[1].cycle <= cutoff {
                ring.pop_front();
            }
            for (wi, window) in SloWindow::ALL.into_iter().enumerate() {
                let burn = self.burn_rate(class, window);
                if burn.is_nan() {
                    continue; // too little traffic in the window to act
                }
                let threshold = match window {
                    SloWindow::Fast => self.policy.fast_burn,
                    SloWindow::Slow => self.policy.slow_burn,
                };
                let should = burn >= threshold;
                if should != self.active[ci][wi] {
                    self.active[ci][wi] = should;
                    events.push(SloEvent {
                        epoch,
                        cycle,
                        class,
                        window,
                        kind: if should { SloEventKind::Raise } else { SloEventKind::Clear },
                        burn_rate: burn,
                    });
                }
            }
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> SloPolicy {
        SloPolicy {
            objective: 0.1,
            fast_window_cycles: 100.0,
            slow_window_cycles: 1000.0,
            fast_burn: 5.0,
            slow_burn: 2.0,
            min_requests: 5,
        }
    }

    fn only_interactive(completed: u64, violated: u64) -> [(u64, u64); NUM_CLASSES] {
        let mut c = [(0, 0); NUM_CLASSES];
        c[0] = (completed, violated);
        c
    }

    #[test]
    fn quiet_traffic_never_alerts() {
        let mut m = SloMonitor::new(policy());
        for e in 0..20 {
            let ev = m.observe(e, (e + 1) as f64 * 50.0, &only_interactive((e + 1) * 10, 0));
            assert!(ev.is_empty(), "epoch {e} alerted on zero violations");
        }
        assert_eq!(m.active_count(), 0);
    }

    #[test]
    fn cliff_raises_fast_then_clears() {
        let mut m = SloMonitor::new(policy());
        // Healthy for 10 epochs, then every completion violates.
        for e in 0..10u64 {
            assert!(m.observe(e, (e + 1) as f64 * 50.0, &only_interactive((e + 1) * 10, 0)).is_empty());
        }
        let ev = m.observe(10, 550.0, &only_interactive(110, 10));
        assert!(
            ev.iter().any(|e| e.window == SloWindow::Fast && e.kind == SloEventKind::Raise),
            "a 100% violation burst must trip the fast window: {ev:?}"
        );
        let raised = ev[0];
        assert_eq!(raised.cycle, 550.0);
        assert!(raised.burn_rate >= 5.0);
        // Back to healthy: the fast window forgets the burst and clears.
        let mut cleared = false;
        for e in 11..20u64 {
            let evs = m.observe(e, (e + 1) as f64 * 50.0, &only_interactive((e + 1) * 10 + 10, 10));
            cleared |= evs
                .iter()
                .any(|e| e.window == SloWindow::Fast && e.kind == SloEventKind::Clear);
        }
        assert!(cleared, "recovery must clear the fast alert");
    }

    #[test]
    fn slow_bleed_trips_the_slow_window_only() {
        let mut m = SloMonitor::new(policy());
        // 25% violations forever: burn 2.5 — above slow_burn (2.0),
        // below fast_burn (5.0).
        let mut raised_windows = Vec::new();
        for e in 0..30u64 {
            let done = (e + 1) * 20;
            for ev in m.observe(e, (e + 1) as f64 * 50.0, &only_interactive(done, done / 4)) {
                if ev.kind == SloEventKind::Raise {
                    raised_windows.push(ev.window);
                }
            }
        }
        assert!(raised_windows.contains(&SloWindow::Slow), "slow bleed must raise the slow window");
        assert!(!raised_windows.contains(&SloWindow::Fast), "burn 2.5 is below the fast threshold");
    }

    #[test]
    fn min_requests_gates_state_changes() {
        let mut m = SloMonitor::new(policy());
        // 2 completions, both violating: burn would be 10/objective but
        // the window holds fewer than min_requests completions.
        let ev = m.observe(0, 50.0, &only_interactive(2, 2));
        assert!(ev.is_empty(), "thin traffic must not page");
        assert!(m.burn_rate(TrafficClass::Interactive, SloWindow::Fast).is_nan());
    }

    #[test]
    fn snapshot_ring_is_bounded() {
        let mut m = SloMonitor::new(policy());
        for e in 0..10_000u64 {
            m.observe(e, (e + 1) as f64 * 50.0, &only_interactive((e + 1) * 10, 0));
        }
        // slow_window / epoch_spacing = 1000 / 50 = 20 snapshots, +1
        // for the baseline candidate and +1 slack for the boundary.
        assert!(
            m.history[0].len() <= 22,
            "ring grew to {} entries — pruning is broken",
            m.history[0].len()
        );
    }

    #[test]
    fn alert_state_is_queryable() {
        let mut m = SloMonitor::new(policy());
        for e in 0..10u64 {
            m.observe(e, (e + 1) as f64 * 50.0, &only_interactive((e + 1) * 10, (e + 1) * 10));
        }
        assert!(m.is_active(TrafficClass::Interactive, SloWindow::Fast));
        assert!(m.is_active(TrafficClass::Interactive, SloWindow::Slow));
        assert!(!m.is_active(TrafficClass::Batch, SloWindow::Fast));
        assert_eq!(m.active_count(), 2);
    }
}
