//! Deterministic observability for the serving stack (substrate S13+).
//!
//! The simulator's aggregate stats say *what* happened; this module
//! says *where the cycles went* and *when* — without breaking the
//! cluster tier's bit-identical-at-any-thread-count contract:
//!
//! * [`profile`] — always-on cycle attribution: every completed
//!   request's latency is split into queue / NoP-distribute / compute /
//!   collect / cap-throttle phases ([`PhaseBreakdown`]) and accumulated
//!   per run, per traffic class, and per package ([`PhaseTotals`]),
//!   surfacing as `*_frac` fields in the stats JSON;
//! * [`span`] — the opt-in request lifecycle recorder: per-request
//!   [`SpanRecord`]s plus shed/preemption instants, gathered shard-
//!   locally and merged in deterministic `(cycle, shard, index)` order.
//!   Disabled, the [`Recorder`] enum costs one discriminant check per
//!   event and zero allocation (bench-guarded in `perf_hotpath`);
//! * [`metrics`] — the metrics registry: log-bucketed streaming
//!   histograms (bucketed by raw IEEE-754 exponent, no libm) and the
//!   per-epoch time series sampled at the `cluster::sync` barrier;
//! * [`export`] — hand-rolled serializers for the metrics JSON and the
//!   Chrome trace-event (Perfetto-loadable) trace behind
//!   `wienna serve|cluster --metrics-out FILE --trace-out FILE`.
//!
//! Schema stability: field names/order for both exports are pinned by
//! `rust/testdata/telemetry_schema.golden`; the CI determinism gate
//! diffs both artifacts across 1/2/4 worker threads.

pub mod export;
pub mod metrics;
pub mod profile;
pub mod span;

pub use export::{chrome_trace, metrics_json};
pub use metrics::{EpochSample, LogHistogram, MetricsRegistry};
pub use profile::{PhaseBreakdown, PhaseTotals, PHASES};
pub use span::{FlowRecord, PreemptSpan, Recorder, ShedSpan, SpanLog, SpanRecord};

use crate::serve::{BatcherConfig, CostCache, ModelKind, PackageSpec};

/// Telemetry knobs carried by `ClusterConfig` (and the serve CLI).
#[derive(Debug, Clone, Copy, Default)]
pub struct TelemetryConfig {
    /// Arm the span recorder and the epoch-series sampler. The
    /// always-on attribution sums are collected regardless.
    pub enabled: bool,
}

/// A run's collected telemetry: the merged span log plus the metrics
/// registry. Lives behind `Option<Box<_>>` on `ClusterStats` so the
/// disabled path pays one pointer of storage.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    pub log: SpanLog,
    pub metrics: MetricsRegistry,
}

impl Telemetry {
    /// Seal the run: order the merged span log deterministically and
    /// stream every span through the histograms. Call once, after all
    /// shard logs are absorbed.
    pub fn finish(&mut self) {
        self.log.sort_chronological();
        for s in &self.log.spans {
            self.metrics.latency_ms.record(crate::serve::cycles_to_ms(s.completed - s.arrival));
            self.metrics.queue_wait_ms.record(crate::serve::cycles_to_ms(s.phases.queue));
            self.metrics.batch_size.record(s.batch as f64);
        }
    }
}

/// Pre-populate the process-global `cost::memo` table, single-threaded,
/// with every `(package design, model, candidate batch)` the run can
/// ask for.
///
/// The memo's hit/miss/eviction counters are process-global relaxed
/// atomics, so a multi-threaded run that *misses* would split the
/// counts nondeterministically across thread schedules. After this
/// warm-up the parallel run only ever hits, and the counters reported
/// under `--metrics-out` are identical at any thread count.
pub fn prewarm_cost_model(specs: &[PackageSpec], kinds: &[ModelKind], batcher: &BatcherConfig) {
    let mut cache = CostCache::new();
    for spec in specs {
        let engine = crate::cost::CostEngine::for_design_point(&spec.sys, spec.dp);
        for &kind in kinds {
            for &batch in batcher.candidates.iter().filter(|&&b| b <= batcher.max_batch) {
                let _ = cache.get(&engine, spec.dp, kind, batch, spec.local_buffer_bytes);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignPoint;

    #[test]
    fn finish_orders_and_fills_histograms() {
        let mut t = Telemetry::default();
        for (arr, disp, comp) in [(0.0, 5.0, 30.0), (0.0, 1.0, 10.0)] {
            t.log.spans.push(SpanRecord {
                id: 0,
                kind: ModelKind::TinyCnn,
                class: None,
                shard: 0,
                package: 0,
                batch: 2,
                arrival: arr,
                dispatched: disp,
                completed: comp,
                phases: PhaseBreakdown { queue: disp - arr, ..Default::default() },
            });
        }
        t.finish();
        assert_eq!(t.metrics.latency_ms.count, 2);
        assert_eq!(t.metrics.batch_size.count, 2);
        assert!(t.log.spans[0].completed <= t.log.spans[1].completed);
    }

    #[test]
    fn prewarm_sweeps_the_candidate_grid() {
        // The memo counters are process-global (other tests mutate them
        // concurrently), so this is a smoke test: the sweep completes,
        // honors the max_batch filter, and leaves the table readable.
        // The actual guarantee — byte-identical memo counters at any
        // thread count after a warm-up — is pinned by the CI
        // determinism gate diffing `--metrics-out` artifacts.
        let specs = PackageSpec::homogeneous(2, DesignPoint::WIENNA_C);
        let batcher = BatcherConfig { max_batch: 2, candidates: vec![1, 2, 4] };
        prewarm_cost_model(&specs, &[ModelKind::TinyCnn], &batcher);
        let s = crate::cost::memo::stats();
        assert!(s.capacity > 0);
    }
}
