//! Deterministic observability for the serving stack (substrate S13+).
//!
//! The simulator's aggregate stats say *what* happened; this module
//! says *where the cycles went* and *when* — without breaking the
//! cluster tier's bit-identical-at-any-thread-count contract:
//!
//! * [`profile`] — always-on cycle attribution: every completed
//!   request's latency is split into queue / NoP-distribute / compute /
//!   collect / cap-throttle phases ([`PhaseBreakdown`]) and accumulated
//!   per run, per traffic class, and per package ([`PhaseTotals`]),
//!   surfacing as `*_frac` fields in the stats JSON;
//! * [`span`] — the opt-in request lifecycle recorder: per-request
//!   [`SpanRecord`]s plus shed/preemption instants, gathered shard-
//!   locally and merged in deterministic `(cycle, shard, index)` order.
//!   Disabled, the [`Recorder`] enum costs one discriminant check per
//!   event and zero allocation (bench-guarded in `perf_hotpath`);
//! * [`metrics`] — the metrics registry: log-bucketed streaming
//!   histograms (bucketed by raw IEEE-754 exponent, no libm; quantile
//!   estimation with a one-bucket error bound) and the per-epoch time
//!   series sampled at the `cluster::sync` barrier;
//! * [`sketch`] — the mergeable relative-error quantile sketch behind
//!   `--bounded-stats --quantile-error EPS`: linear mantissa-prefix
//!   sub-buckets per octave (no libm), integer-exact merges at the sync
//!   barrier in shard-major order, collapsible low tail;
//! * [`slo`] — the deterministic multi-window SLO burn-rate monitor,
//!   evaluated single-threaded at the epoch barrier; raise/clear events
//!   carry exact cycles and surface in the stats and metrics exports;
//! * [`export`] — hand-rolled serializers for the metrics JSON (plus
//!   the `wienna-metrics-stream-v1` incremental JSONL writer and its
//!   reconstructor) and the Chrome trace-event (Perfetto-loadable)
//!   trace behind `wienna serve|cluster --metrics-out FILE --trace-out
//!   FILE`.
//!
//! Schema stability: field names/order for both exports are pinned by
//! `rust/testdata/telemetry_schema.golden`; the CI determinism gate
//! diffs both artifacts (buffered and streaming) across 1/2/4 worker
//! threads.

pub mod export;
pub mod metrics;
pub mod profile;
pub mod sketch;
pub mod slo;
pub mod span;

pub use export::{
    chrome_trace, metrics_json, metrics_json_summary, metrics_json_summary_with,
    metrics_json_with, stream_to_metrics_v1, MetricsStreamWriter, NamedSketch,
    NonBlockingLineSink, METRICS_STREAM_SCHEMA,
};
pub use metrics::{EpochSample, LogHistogram, MetricsRegistry};
pub use profile::{PhaseBreakdown, PhaseTotals, PHASES};
pub use sketch::{QuantileSketch, DEFAULT_QUANTILE_ERROR};
pub use slo::{SloEvent, SloEventKind, SloMonitor, SloPolicy, SloWindow};
pub use span::{FlowRecord, PreemptSpan, Recorder, ShedSpan, SpanLog, SpanRecord};

use crate::serve::{BatcherConfig, CostCache, ModelKind, PackageSpec};

/// Telemetry knobs carried by `ClusterConfig` (and the serve CLI).
#[derive(Debug, Clone, Copy)]
pub struct TelemetryConfig {
    /// Arm the metrics registry and the epoch-series sampler (and, via
    /// `spans`, the span recorder). The always-on attribution sums are
    /// collected regardless.
    pub enabled: bool,
    /// Record per-request lifecycle spans (required for `--trace-out`).
    /// The one O(requests) telemetry surface — `bounded` mode leaves it
    /// off and feeds the histograms from the event stream instead.
    pub spans: bool,
    /// Bounded-memory stats (`--bounded-stats`): percentiles come off
    /// mergeable quantile sketches and the per-request latency `Vec` is
    /// never grown — O(buckets + epochs) telemetry for million-request
    /// traces, within `quantile_error` of the exact path.
    pub bounded: bool,
    /// Relative error ε of the bounded-mode quantile sketches
    /// (`--quantile-error`); only consulted when `bounded` is set.
    pub quantile_error: f64,
    /// Burn-rate policy for the epoch-barrier SLO monitor.
    pub slo: SloPolicy,
}

// Manual impl (not derived) so `..Default::default()` construction sites
// get a *usable* sketch resolution instead of ε = 0.0.
impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            enabled: false,
            spans: false,
            bounded: false,
            quantile_error: DEFAULT_QUANTILE_ERROR,
            slo: SloPolicy::default(),
        }
    }
}

impl TelemetryConfig {
    /// Full-fidelity telemetry: spans + registry (the pre-bounded
    /// default behind `--trace-out`/`--metrics-out`).
    pub fn enabled() -> Self {
        TelemetryConfig { enabled: true, spans: true, ..Default::default() }
    }

    /// Bounded-memory telemetry: registry only, sketch percentiles at
    /// the default ε, no span log and no per-request `Vec`s.
    pub fn bounded() -> Self {
        TelemetryConfig { enabled: true, bounded: true, ..Default::default() }
    }

    /// Bounded-memory telemetry at an explicit sketch resolution.
    pub fn bounded_with(quantile_error: f64) -> Self {
        TelemetryConfig { quantile_error, ..Self::bounded() }
    }
}

/// A run's collected telemetry: the merged span log plus the metrics
/// registry. Lives behind `Option<Box<_>>` on `ClusterStats` so the
/// disabled path pays one pointer of storage.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    pub log: SpanLog,
    pub metrics: MetricsRegistry,
    /// Bounded mode: the histograms were fed incrementally from the
    /// deterministic event merge, so [`Telemetry::finish`] must not
    /// stream the (empty) span log over them again.
    pub bounded: bool,
}

impl Telemetry {
    /// Seal the run: order the merged span log deterministically and
    /// stream every span through the histograms (fleet-wide and
    /// per-class tracks). Call once, after all shard logs are absorbed.
    /// In bounded mode the histograms were already fed at the event
    /// merge — only the ordering pass runs.
    pub fn finish(&mut self) {
        self.log.sort_chronological();
        if self.bounded {
            return;
        }
        for s in &self.log.spans {
            let latency = crate::serve::cycles_to_ms(s.completed - s.arrival);
            let queue = crate::serve::cycles_to_ms(s.phases.queue);
            self.metrics.latency_ms.record(latency);
            self.metrics.queue_wait_ms.record(queue);
            self.metrics.batch_size.record(s.batch as f64);
            if let Some(class) = s.class {
                self.metrics.class_latency_ms[class.index()].record(latency);
                self.metrics.class_queue_wait_ms[class.index()].record(queue);
            }
        }
    }
}

/// Pre-populate the process-global `cost::memo` table, single-threaded,
/// with every `(package design, model, candidate batch)` the run can
/// ask for.
///
/// The memo's hit/miss/eviction counters are process-global relaxed
/// atomics, so a multi-threaded run that *misses* would split the
/// counts nondeterministically across thread schedules. After this
/// warm-up the parallel run only ever hits, and the counters reported
/// under `--metrics-out` are identical at any thread count.
pub fn prewarm_cost_model(specs: &[PackageSpec], kinds: &[ModelKind], batcher: &BatcherConfig) {
    let mut cache = CostCache::new();
    for spec in specs {
        let engine = crate::cost::CostEngine::for_design_point(&spec.sys, spec.dp);
        for &kind in kinds {
            for &batch in batcher.candidates.iter().filter(|&&b| b <= batcher.max_batch) {
                let _ = cache.get(&engine, spec.dp, kind, batch, spec.local_buffer_bytes);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TrafficClass;
    use crate::config::DesignPoint;

    #[test]
    fn finish_orders_and_fills_histograms() {
        let mut t = Telemetry::default();
        for (arr, disp, comp) in [(0.0, 5.0, 30.0), (0.0, 1.0, 10.0)] {
            t.log.spans.push(SpanRecord {
                id: 0,
                kind: ModelKind::TinyCnn,
                class: Some(TrafficClass::Batch),
                shard: 0,
                package: 0,
                batch: 2,
                arrival: arr,
                dispatched: disp,
                completed: comp,
                phases: PhaseBreakdown { queue: disp - arr, ..Default::default() },
            });
        }
        t.finish();
        assert_eq!(t.metrics.latency_ms.count, 2);
        assert_eq!(t.metrics.batch_size.count, 2);
        assert_eq!(t.metrics.class_latency_ms[TrafficClass::Batch.index()].count, 2);
        assert_eq!(t.metrics.class_queue_wait_ms[TrafficClass::Batch.index()].count, 2);
        assert_eq!(t.metrics.class_latency_ms[TrafficClass::Interactive.index()].count, 0);
        assert!(t.log.spans[0].completed <= t.log.spans[1].completed);
    }

    #[test]
    fn bounded_finish_leaves_prefed_histograms_alone() {
        let mut t = Telemetry { bounded: true, ..Default::default() };
        t.metrics.latency_ms.record(3.0);
        t.finish();
        assert_eq!(t.metrics.latency_ms.count, 1, "finish must not double-count bounded feeds");
    }

    #[test]
    fn config_constructors_pick_consistent_modes() {
        let full = TelemetryConfig::enabled();
        assert!(full.enabled && full.spans && !full.bounded);
        let bounded = TelemetryConfig::bounded();
        assert!(bounded.enabled && !bounded.spans && bounded.bounded);
        assert_eq!(bounded.quantile_error, DEFAULT_QUANTILE_ERROR);
        let fine = TelemetryConfig::bounded_with(0.001);
        assert!(fine.bounded && fine.quantile_error == 0.001);
        assert_eq!(TelemetryConfig::default().quantile_error, DEFAULT_QUANTILE_ERROR);
    }

    #[test]
    fn prewarm_sweeps_the_candidate_grid() {
        // The memo counters are process-global (other tests mutate them
        // concurrently), so this is a smoke test: the sweep completes,
        // honors the max_batch filter, and leaves the table readable.
        // The actual guarantee — byte-identical memo counters at any
        // thread count after a warm-up — is pinned by the CI
        // determinism gate diffing `--metrics-out` artifacts.
        let specs = PackageSpec::homogeneous(2, DesignPoint::WIENNA_C);
        let batcher = BatcherConfig { max_batch: 2, candidates: vec![1, 2, 4] };
        prewarm_cost_model(&specs, &[ModelKind::TinyCnn], &batcher);
        let s = crate::cost::memo::stats();
        assert!(s.capacity > 0);
    }
}
