//! Request lifecycle spans and the opt-in span recorder.
//!
//! A [`SpanRecord`] captures one completed request's deterministic
//! timeline (arrived → dispatched → completed) plus its
//! [`PhaseBreakdown`]; [`ShedSpan`] and [`PreemptSpan`] capture the
//! terminal/interrupt events. Recording is **opt-in**: the hot paths
//! hold a [`Recorder`] enum whose disabled arm is a single discriminant
//! check — no per-request allocation, no branch-heavy bookkeeping
//! (bench-guarded in `benches/perf_hotpath.rs`).
//!
//! Determinism: each shard appends to its private [`SpanLog`] in local
//! simulated-time order; `cluster::merge::finalize` absorbs the logs in
//! shard-id order and [`SpanLog::sort_chronological`] stable-sorts by
//! cycle, so the merged log is ordered by `(cycle, shard, emission
//! index)` at any thread count.

use crate::cluster::{ShedReason, TrafficClass};
use crate::serve::ModelKind;

use super::profile::PhaseBreakdown;

/// One completed request's lifecycle span.
#[derive(Debug, Clone, Copy)]
pub struct SpanRecord {
    pub id: u64,
    pub kind: ModelKind,
    /// Traffic class (`None` on the single-tenant `serve` path).
    pub class: Option<TrafficClass>,
    /// Owning shard (0 on the `serve` path; stamped by the merge).
    pub shard: usize,
    /// Package the batch ran on (shard-local index).
    pub package: usize,
    /// Requests in the batch this span rode.
    pub batch: usize,
    /// Arrival cycle.
    pub arrival: f64,
    /// Final successful dispatch cycle.
    pub dispatched: f64,
    /// Completion cycle.
    pub completed: f64,
    /// Cycle-attribution split of `completed - arrival`.
    pub phases: PhaseBreakdown,
}

/// A request refused by admission control or deadline shedding.
#[derive(Debug, Clone, Copy)]
pub struct ShedSpan {
    pub id: u64,
    pub kind: ModelKind,
    pub class: Option<TrafficClass>,
    pub shard: usize,
    /// Arrival cycle.
    pub arrival: f64,
    /// Cycle the shed decision was made.
    pub cycle: f64,
    pub reason: ShedReason,
}

/// A batch aborted by priority preemption (its requests requeue and
/// eventually produce ordinary [`SpanRecord`]s whose queue phase
/// includes the burnt cycles).
#[derive(Debug, Clone, Copy)]
pub struct PreemptSpan {
    /// Cycle the preemption fired.
    pub cycle: f64,
    pub shard: usize,
    pub package: usize,
    /// Requests pushed back to the head of their queues.
    pub batch: usize,
}

/// One cross-shard hand-off at an epoch barrier — a work-steal or a
/// failover re-route off a dead shard. The Chrome trace renders each as
/// a paired flow event (`ph: "s"` on the donor, `ph: "f"` on the
/// victim) so the donor-side enqueue visually links to the victim-side
/// service. Recorded at the single-threaded barrier, so the stream is
/// deterministic by construction.
#[derive(Debug, Clone, Copy)]
pub struct FlowRecord {
    pub id: u64,
    pub class: TrafficClass,
    pub from_shard: usize,
    pub to_shard: usize,
    /// Barrier cycle the hand-off happened at.
    pub cycle: f64,
}

/// Per-shard (or per-fleet) span storage.
#[derive(Debug, Clone, Default)]
pub struct SpanLog {
    pub spans: Vec<SpanRecord>,
    pub sheds: Vec<ShedSpan>,
    pub preemptions: Vec<PreemptSpan>,
    /// Cross-shard hand-offs (barrier-recorded; `absorb` never stamps
    /// these — they already carry both shard ids).
    pub flows: Vec<FlowRecord>,
}

impl SpanLog {
    /// Move `other`'s records in, stamping them with `shard`. Call in
    /// shard-id order — combined with the stable chronological sort
    /// this yields the deterministic `(cycle, shard, index)` order.
    pub fn absorb(&mut self, shard: usize, mut other: SpanLog) {
        for s in &mut other.spans {
            s.shard = shard;
        }
        for s in &mut other.sheds {
            s.shard = shard;
        }
        for s in &mut other.preemptions {
            s.shard = shard;
        }
        self.spans.extend(other.spans);
        self.sheds.extend(other.sheds);
        self.preemptions.extend(other.preemptions);
        self.flows.extend(other.flows);
    }

    /// Stable sort every record stream by its cycle (`total_cmp`:
    /// deterministic even against NaNs). Shard-order ties are preserved
    /// by stability.
    pub fn sort_chronological(&mut self) {
        self.spans.sort_by(|a, b| a.completed.total_cmp(&b.completed));
        self.sheds.sort_by(|a, b| a.cycle.total_cmp(&b.cycle));
        self.preemptions.sort_by(|a, b| a.cycle.total_cmp(&b.cycle));
        self.flows.sort_by(|a, b| a.cycle.total_cmp(&b.cycle));
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
            && self.sheds.is_empty()
            && self.preemptions.is_empty()
            && self.flows.is_empty()
    }
}

/// The opt-in recorder the hot paths hold. `Off` costs one discriminant
/// check per would-be record; `On` boxes the log so the enum stays one
/// word plus tag either way.
#[derive(Debug, Clone, Default)]
pub enum Recorder {
    #[default]
    Off,
    On(Box<SpanLog>),
}

impl Recorder {
    pub fn new(enabled: bool) -> Self {
        if enabled {
            Recorder::On(Box::default())
        } else {
            Recorder::Off
        }
    }

    pub fn is_on(&self) -> bool {
        matches!(self, Recorder::On(_))
    }

    /// The entire disabled-path cost: match, return `None`.
    #[inline]
    pub fn log_mut(&mut self) -> Option<&mut SpanLog> {
        match self {
            Recorder::Off => None,
            Recorder::On(log) => Some(log),
        }
    }

    /// Take the accumulated log, leaving the recorder armed but empty.
    pub fn take_log(&mut self) -> SpanLog {
        match self {
            Recorder::Off => SpanLog::default(),
            Recorder::On(log) => std::mem::take(log),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(completed: f64) -> SpanRecord {
        SpanRecord {
            id: 0,
            kind: ModelKind::TinyCnn,
            class: None,
            shard: 0,
            package: 0,
            batch: 1,
            arrival: 0.0,
            dispatched: 0.0,
            completed,
            phases: PhaseBreakdown::default(),
        }
    }

    #[test]
    fn recorder_off_is_inert() {
        let mut r = Recorder::new(false);
        assert!(!r.is_on());
        assert!(r.log_mut().is_none());
        assert!(r.take_log().is_empty());
    }

    #[test]
    fn absorb_stamps_shard_and_sort_is_stable_across_shards() {
        let mut merged = SpanLog::default();
        // Shard 1 logged cycles [5, 5]; shard 0 logged [5, 2]. After
        // shard-order absorb + stable sort, ties at cycle 5 keep shard
        // order: 0 before 1, 1 before 1's second.
        let a = SpanLog { spans: vec![span(5.0), span(2.0)], ..Default::default() };
        let b = SpanLog { spans: vec![span(5.0), span(5.0)], ..Default::default() };
        merged.absorb(0, a);
        merged.absorb(1, b);
        merged.sort_chronological();
        let order: Vec<(f64, usize)> = merged.spans.iter().map(|s| (s.completed, s.shard)).collect();
        assert_eq!(order, vec![(2.0, 0), (5.0, 0), (5.0, 1), (5.0, 1)]);
    }

    #[test]
    fn take_log_leaves_recorder_armed() {
        let mut r = Recorder::new(true);
        r.log_mut().unwrap().spans.push(span(1.0));
        let log = r.take_log();
        assert_eq!(log.spans.len(), 1);
        assert!(r.is_on());
        assert!(r.take_log().is_empty());
    }
}
