//! Metrics registry: log-bucketed streaming histograms and per-epoch
//! time-series samples.
//!
//! Both are built for determinism first: the histogram buckets by the
//! raw IEEE-754 exponent (bit extraction, no `log2` libm call whose
//! last ulp could differ across platforms), and the epoch series is
//! sampled single-threaded at the `cluster::sync` epoch barrier, so the
//! serialized registry is byte-identical at any thread count.

use std::collections::BTreeMap;

use crate::cluster::NUM_CLASSES;

/// Bucket index of a sample: its unbiased binary exponent, so bucket
/// `k` spans `[2^k, 2^(k+1))`. Zero, negative, and NaN samples land in
/// a single sentinel bucket.
pub fn bucket_index(v: f64) -> i32 {
    if !(v > 0.0) {
        return i32::MIN;
    }
    // Exponent field of the IEEE-754 double, unbiased. Subnormals all
    // collapse into exponent -1023 — far below any cycle/ms quantity
    // this simulator produces.
    (((v.to_bits() >> 52) & 0x7ff) as i32) - 1023
}

/// A streaming histogram over power-of-two buckets.
#[derive(Debug, Clone, Default)]
pub struct LogHistogram {
    /// Bucket exponent → sample count. `BTreeMap` so iteration (and
    /// therefore serialization) is ordered.
    pub buckets: BTreeMap<i32, u64>,
    pub count: u64,
    pub sum: f64,
}

impl LogHistogram {
    pub fn record(&mut self, v: f64) {
        *self.buckets.entry(bucket_index(v)).or_insert(0) += 1;
        self.count += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        self.sum / self.count as f64
    }
}

/// Gauges and cumulative counters captured at one epoch barrier.
#[derive(Debug, Clone, Copy, Default)]
pub struct EpochSample {
    /// Epoch index (0-based).
    pub epoch: u64,
    /// Barrier cycle the sample was taken at.
    pub cycle: f64,
    /// Requests sitting in admission queues across all shards (gauge).
    pub queued: u64,
    /// Batches in flight across all packages (gauge).
    pub in_flight_batches: u64,
    /// Completions so far (cumulative).
    pub completed: u64,
    /// Per-class sheds so far, priority order (cumulative).
    pub shed: [u64; NUM_CLASSES],
    /// Requests rebalanced by work stealing so far (cumulative).
    pub steals: u64,
    /// Power draw of in-flight batches across the fleet (gauge, watts).
    pub power_w: f64,
    /// Fleet-average occupancy of the shared wireless medium so far:
    /// distribution-plane busy cycles over elapsed package-cycles
    /// (gauge; climbs toward `nop::mac::MAC_SATURATION` under
    /// contention).
    pub mac_occupancy: f64,
    /// Cycles dispatches have spent waiting for the shared-medium token
    /// so far (cumulative; exactly 0.0 with contention disabled).
    pub token_wait_cycles: f64,
}

/// The full registry: named histograms plus the epoch time series.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    /// End-to-end request latency (ms).
    pub latency_ms: LogHistogram,
    /// Queue-phase wait per request (ms).
    pub queue_wait_ms: LogHistogram,
    /// Dispatched batch sizes.
    pub batch_size: LogHistogram,
    /// One sample per epoch barrier, epoch order.
    pub epochs: Vec<EpochSample>,
}

impl MetricsRegistry {
    /// Histograms with their pinned serialization names, emission order.
    pub fn histograms(&self) -> [(&'static str, &LogHistogram); 3] {
        [
            ("latency_ms", &self.latency_ms),
            ("queue_wait_ms", &self.queue_wait_ms),
            ("batch_size", &self.batch_size),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_binary_exponents() {
        assert_eq!(bucket_index(1.0), 0);
        assert_eq!(bucket_index(1.5), 0);
        assert_eq!(bucket_index(2.0), 1);
        assert_eq!(bucket_index(3.99), 1);
        assert_eq!(bucket_index(0.25), -2);
        assert_eq!(bucket_index(1024.0), 10);
    }

    #[test]
    fn nonpositive_and_nan_hit_the_sentinel() {
        assert_eq!(bucket_index(0.0), i32::MIN);
        assert_eq!(bucket_index(-4.0), i32::MIN);
        assert_eq!(bucket_index(f64::NAN), i32::MIN);
    }

    #[test]
    fn histogram_streams_count_and_sum() {
        let mut h = LogHistogram::default();
        for v in [1.0, 1.9, 4.0, 0.0] {
            h.record(v);
        }
        assert_eq!(h.count, 4);
        assert_eq!(h.buckets[&0], 2);
        assert_eq!(h.buckets[&2], 1);
        assert_eq!(h.buckets[&i32::MIN], 1);
        crate::assert_close!(h.sum, 6.9);
    }
}
