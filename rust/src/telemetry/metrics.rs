//! Metrics registry: log-bucketed streaming histograms and per-epoch
//! time-series samples.
//!
//! Both are built for determinism first: the histogram buckets by the
//! raw IEEE-754 exponent (bit extraction, no `log2` libm call whose
//! last ulp could differ across platforms), and the epoch series is
//! sampled single-threaded at the `cluster::sync` epoch barrier, so the
//! serialized registry is byte-identical at any thread count.
//!
//! These histograms serialize into the metrics artifacts (schema-pinned
//! names and bucket exponents). The bounded-memory percentile store
//! behind `--bounded-stats` is the finer-grained, mergeable
//! [`crate::telemetry::QuantileSketch`] (same no-libm bit-extraction
//! idea, `--quantile-error`-many linear sub-buckets per octave); at
//! `sub_bits = 0` its buckets coincide with [`LogHistogram`]'s octaves,
//! which a sketch unit test pins.

use std::collections::BTreeMap;

use crate::cluster::{TrafficClass, NUM_CLASSES};

/// Bucket index of a sample: its unbiased binary exponent, so bucket
/// `k` spans `[2^k, 2^(k+1))`. Zero, negative, and NaN samples land in
/// a single sentinel bucket.
pub fn bucket_index(v: f64) -> i32 {
    if !(v > 0.0) {
        return i32::MIN;
    }
    // Exponent field of the IEEE-754 double, unbiased. Subnormals all
    // collapse into exponent -1023 — far below any cycle/ms quantity
    // this simulator produces.
    (((v.to_bits() >> 52) & 0x7ff) as i32) - 1023
}

/// Lower bound `2^k` of bucket `k`, assembled by bit manipulation (no
/// libm, same determinism rationale as [`bucket_index`]). Clamped to
/// the normal-double exponent range; the simulator's ms-scale samples
/// never leave it.
fn bucket_lo(k: i32) -> f64 {
    let e = (k + 1023).clamp(1, 2046) as u64;
    f64::from_bits(e << 52)
}

/// A streaming histogram over power-of-two buckets.
#[derive(Debug, Clone, Default)]
pub struct LogHistogram {
    /// Bucket exponent → sample count. `BTreeMap` so iteration (and
    /// therefore serialization) is ordered.
    pub buckets: BTreeMap<i32, u64>,
    pub count: u64,
    pub sum: f64,
}

impl LogHistogram {
    pub fn record(&mut self, v: f64) {
        *self.buckets.entry(bucket_index(v)).or_insert(0) += 1;
        self.count += 1;
        self.sum += v;
    }

    pub fn mean(&self) -> f64 {
        self.sum / self.count as f64
    }

    /// Estimate the `p`-th percentile (nearest-rank convention, the
    /// same one `serve::stats::LatencyRecorder` uses) from the bucket
    /// counts alone, in O(buckets).
    ///
    /// The rank is resolved exactly — bucket counts are exact — then
    /// the value is interpolated linearly inside the bucket: rank
    /// fraction `f ∈ (0, 1]` of bucket `k` maps to `2^k · (1 + f)`.
    ///
    /// **Error bound:** the estimate and the exact nearest-rank sample
    /// always share bucket `[2^k, 2^(k+1))` (estimate in `(2^k, 2^(k+1)]`,
    /// exact in `[2^k, 2^(k+1))`), so `estimate / exact ∈ (1/2, 2]` —
    /// within one power-of-two bucket. Pinned against the exact-`Vec`
    /// oracle across seeded load sweeps in `rust/tests/telemetry.rs`.
    ///
    /// Returns NaN when empty and 0.0 when the rank lands in the
    /// sentinel bucket (non-positive samples), mirroring the recorder.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let n = self.count;
        let rank = (((p / 100.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut before = 0u64;
        for (&k, &c) in &self.buckets {
            if before + c >= rank {
                if k == i32::MIN {
                    return 0.0;
                }
                let frac = (rank - before) as f64 / c as f64;
                return bucket_lo(k) * (1.0 + frac);
            }
            before += c;
        }
        f64::NAN // unreachable: bucket counts sum to `count`
    }
}

/// Gauges and cumulative counters captured at one epoch barrier.
#[derive(Debug, Clone, Default)]
pub struct EpochSample {
    /// Epoch index (0-based).
    pub epoch: u64,
    /// Barrier cycle the sample was taken at.
    pub cycle: f64,
    /// Requests sitting in admission queues across all shards (gauge).
    pub queued: u64,
    /// Batches in flight across all packages (gauge).
    pub in_flight_batches: u64,
    /// Completions so far (cumulative).
    pub completed: u64,
    /// Per-class sheds so far, priority order (cumulative).
    pub shed: [u64; NUM_CLASSES],
    /// Requests rebalanced by work stealing so far (cumulative).
    pub steals: u64,
    /// Power draw of in-flight batches across the fleet (gauge, watts).
    pub power_w: f64,
    /// Fleet-average occupancy of the shared wireless medium so far:
    /// distribution-plane busy cycles over elapsed package-cycles
    /// (gauge; climbs toward `nop::mac::MAC_SATURATION` under
    /// contention).
    pub mac_occupancy: f64,
    /// Cycles dispatches have spent waiting for the shared-medium token
    /// so far (cumulative; exactly 0.0 with contention disabled).
    pub token_wait_cycles: f64,
    /// Per-package MAC occupancy so far, shard-major package order
    /// (gauge; the fleet-wide `mac_occupancy` is their mean). Localizes
    /// *which* package is burning the shared medium.
    pub mac_occupancy_by_pkg: Vec<f64>,
    /// Per-package token-wait cycles so far, shard-major package order
    /// (cumulative; sums to `token_wait_cycles`).
    pub token_wait_by_pkg: Vec<f64>,
}

/// The full registry: named histograms plus the epoch time series.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    /// End-to-end request latency (ms).
    pub latency_ms: LogHistogram,
    /// Queue-phase wait per request (ms).
    pub queue_wait_ms: LogHistogram,
    /// Dispatched batch sizes.
    pub batch_size: LogHistogram,
    /// Per-class end-to-end latency (ms), priority order.
    pub class_latency_ms: [LogHistogram; NUM_CLASSES],
    /// Per-class queue-phase wait (ms), priority order.
    pub class_queue_wait_ms: [LogHistogram; NUM_CLASSES],
    /// One sample per epoch barrier, epoch order.
    pub epochs: Vec<EpochSample>,
    /// SLO burn-rate raise/clear events, epoch order (filled by the
    /// `telemetry::slo` monitor at the sync barrier).
    pub slo_events: Vec<crate::telemetry::slo::SloEvent>,
}

impl MetricsRegistry {
    /// Histograms with their pinned serialization names, emission
    /// order: the three fleet-wide histograms, then the per-class
    /// latency and queue-wait tracks (class labels `-` → `_`).
    pub fn histograms(&self) -> Vec<(String, &LogHistogram)> {
        let mut out: Vec<(String, &LogHistogram)> = vec![
            ("latency_ms".into(), &self.latency_ms),
            ("queue_wait_ms".into(), &self.queue_wait_ms),
            ("batch_size".into(), &self.batch_size),
        ];
        for (class, h) in TrafficClass::ALL.iter().zip(&self.class_latency_ms) {
            out.push((format!("latency_ms_{}", class.label().replace('-', "_")), h));
        }
        for (class, h) in TrafficClass::ALL.iter().zip(&self.class_queue_wait_ms) {
            out.push((format!("queue_wait_ms_{}", class.label().replace('-', "_")), h));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_binary_exponents() {
        assert_eq!(bucket_index(1.0), 0);
        assert_eq!(bucket_index(1.5), 0);
        assert_eq!(bucket_index(2.0), 1);
        assert_eq!(bucket_index(3.99), 1);
        assert_eq!(bucket_index(0.25), -2);
        assert_eq!(bucket_index(1024.0), 10);
    }

    #[test]
    fn nonpositive_and_nan_hit_the_sentinel() {
        assert_eq!(bucket_index(0.0), i32::MIN);
        assert_eq!(bucket_index(-4.0), i32::MIN);
        assert_eq!(bucket_index(f64::NAN), i32::MIN);
    }

    #[test]
    fn histogram_streams_count_and_sum() {
        let mut h = LogHistogram::default();
        for v in [1.0, 1.9, 4.0, 0.0] {
            h.record(v);
        }
        assert_eq!(h.count, 4);
        assert_eq!(h.buckets[&0], 2);
        assert_eq!(h.buckets[&2], 1);
        assert_eq!(h.buckets[&i32::MIN], 1);
        crate::assert_close!(h.sum, 6.9);
    }

    #[test]
    fn bucket_lo_inverts_bucket_index() {
        for k in [-10, -1, 0, 1, 7, 40] {
            let lo = bucket_lo(k);
            assert_eq!(bucket_index(lo), k, "2^{k} opens bucket {k}");
            assert_eq!(bucket_index(lo * 1.999), k, "bucket {k} spans up to 2^{}", k + 1);
        }
        assert_eq!(bucket_lo(0), 1.0);
        assert_eq!(bucket_lo(3), 8.0);
        assert_eq!(bucket_lo(-2), 0.25);
    }

    #[test]
    fn quantile_is_empty_nan_and_sentinel_zero() {
        let h = LogHistogram::default();
        assert!(h.quantile(50.0).is_nan());
        let mut h = LogHistogram::default();
        h.record(0.0);
        h.record(0.0);
        assert_eq!(h.quantile(50.0), 0.0);
    }

    #[test]
    fn quantile_interpolates_within_the_bucket() {
        let mut h = LogHistogram::default();
        // Four samples in bucket 0 ([1, 2)): ranks 1..=4 interpolate at
        // fractions 1/4, 2/4, 3/4, 4/4 of the bucket.
        for _ in 0..4 {
            h.record(1.5);
        }
        crate::assert_close!(h.quantile(25.0), 1.25);
        crate::assert_close!(h.quantile(50.0), 1.5);
        crate::assert_close!(h.quantile(75.0), 1.75);
        crate::assert_close!(h.quantile(100.0), 2.0);
        // A fifth sample in bucket 2 ([4, 8)) absorbs the top rank.
        h.record(5.0);
        crate::assert_close!(h.quantile(100.0), 8.0);
        crate::assert_close!(h.quantile(80.0), 1.0 + 4.0 / 4.0);
    }

    #[test]
    fn quantile_stays_within_one_bucket_of_the_exact_rank() {
        // Deterministic pseudo-random sweep: the estimate and the exact
        // nearest-rank sample must share a power-of-two bucket, i.e.
        // estimate/exact ∈ (1/2, 2] — the documented bound.
        let mut h = LogHistogram::default();
        let mut samples = Vec::new();
        let mut x = 9u64;
        for _ in 0..500 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = 0.01 + (x >> 11) as f64 / (1u64 << 53) as f64 * 80.0;
            h.record(v);
            samples.push(v);
        }
        samples.sort_by(f64::total_cmp);
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let n = samples.len();
            let rank = (((p / 100.0) * n as f64).ceil() as usize).clamp(1, n);
            let exact = samples[rank - 1];
            let est = h.quantile(p);
            let ratio = est / exact;
            assert!(
                ratio > 0.5 && ratio <= 2.0,
                "p{p}: estimate {est} vs exact {exact} outside the one-bucket bound"
            );
            assert_eq!(
                if est == bucket_lo(bucket_index(est)) { bucket_index(est) - 1 } else { bucket_index(est) },
                bucket_index(exact),
                "p{p}: estimate {est} left the exact sample's bucket ({exact})"
            );
        }
    }

    #[test]
    fn histograms_expose_per_class_tracks_in_order() {
        let mut r = MetricsRegistry::default();
        r.class_latency_ms[0].record(1.0);
        let names: Vec<String> = r.histograms().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names[..3], ["latency_ms", "queue_wait_ms", "batch_size"]);
        assert_eq!(names.len(), 3 + 2 * NUM_CLASSES);
        assert!(names[3].starts_with("latency_ms_"));
        assert!(names[3 + NUM_CLASSES].starts_with("queue_wait_ms_"));
        assert!(!names.iter().any(|n| n.contains('-')), "labels are snake_cased");
    }
}
