//! Serializers: the metrics JSON, the `wienna-metrics-stream-v1`
//! incremental JSONL writer (and its reconstructor), and the Chrome
//! trace-event export.
//!
//! All are hand-rolled like `ClusterStats::to_json` — no JSON crate —
//! and deterministic: every number renders through `format!("{v}")`
//! (shortest round-trip), every collection iterates in a fixed order,
//! and non-finite values become `null`. The field names and their order
//! are pinned by `rust/testdata/telemetry_schema.golden`; update that
//! fixture only for a deliberate schema change.
//!
//! ## Streaming
//!
//! The buffered artifact ([`metrics_json`]) holds the whole epoch
//! series in memory until the run ends. The streaming mode instead
//! appends one JSONL line per epoch barrier as the run progresses
//! ([`MetricsStreamWriter`]): a schema header, `{"epoch_sample": ...}`
//! lines carrying exactly the text the buffered export would have
//! placed in its `epochs` array, `{"slo_event": ...}` lines the moment
//! a burn-rate alert raises or clears, and a final `{"summary": "..."}`
//! line holding the buffered artifact with an *empty* epochs array.
//! [`stream_to_metrics_v1`] splices the epoch lines back into the
//! summary's empty slot — reproducing [`metrics_json`]'s output **byte
//! for byte** by construction, which is what the CI determinism gate
//! checks across 1/2/4 worker threads.
//!
//! For *live* export (`--metrics-out tcp://HOST:PORT`) the same line
//! protocol rides a non-blocking socket through
//! [`NonBlockingLineSink`]: whole lines only, a bounded backlog that
//! drops oldest-first under backpressure, and a post-run grace drain —
//! so a slow or dead dashboard can never stall an epoch barrier or
//! perturb the simulation.
//!
//! The trace export follows the Chrome trace-event format (the JSON
//! Perfetto and `chrome://tracing` load): `"X"` complete slices for
//! request spans, `"i"` instants for sheds/preemptions, `"s"`/`"f"`
//! flow pairs linking a cross-shard hand-off's donor enqueue to its
//! victim-side service, `"C"` counters for the per-epoch gauges, and
//! `"M"` process-name metadata per shard. Timestamps are microseconds
//! of simulated time.

use std::collections::VecDeque;
use std::io::{self, Write};

use crate::cluster::{TrafficClass, NUM_CLASSES};
use crate::cost::memo::MemoStats;
use crate::serve::cycles_to_ms;

use super::metrics::EpochSample;
use super::profile::PhaseTotals;
use super::sketch::QuantileSketch;
use super::slo::{SloEvent, SloEventKind};
use super::Telemetry;

/// One named quantile sketch bound for the artifact's `sketches` block.
pub type NamedSketch<'a> = (String, &'a QuantileSketch);

fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn num_list(vs: &[f64]) -> String {
    vs.iter().map(|&v| num(v)).collect::<Vec<_>>().join(", ")
}

/// Dist-phase blowup alarm threshold: when completed requests spend
/// this fraction (or more) of their end-to-end cycles in the `dist`
/// phase, the shared wireless medium is the bottleneck — expected under
/// injected contention (`wienna::fault`), a red flag otherwise. The
/// metrics JSON carries the verdict as `"dist_alarm"`.
pub const DIST_ALARM_FRAC: f64 = 0.4;

/// Schema tag on the first line of a streamed metrics artifact.
pub const METRICS_STREAM_SCHEMA: &str = "wienna-metrics-stream-v1";

/// Simulated cycle → trace-event timestamp (µs).
fn ts_us(cycle: f64) -> f64 {
    cycles_to_ms(cycle) * 1000.0
}

fn frac_fields(indent: &str, t: &PhaseTotals) -> String {
    let f = t.fractions();
    let mut s = String::new();
    for (name, v) in super::profile::PHASES.iter().zip(f) {
        s.push_str(&format!("{indent}\"{name}_frac\": {},\n", num(v)));
    }
    s
}

/// One epoch sample as a single-line JSON object — shared verbatim by
/// the buffered `epochs` array and the streamed `epoch_sample` lines,
/// so reconstruction is byte-exact by construction.
fn epoch_json(e: &EpochSample) -> String {
    let mut s = format!(
        "{{ \"epoch\": {}, \"cycle\": {}, \"queued\": {}, \"in_flight_batches\": {}, \
         \"completed\": {}",
        e.epoch,
        num(e.cycle),
        e.queued,
        e.in_flight_batches,
        e.completed
    );
    for (class, shed) in TrafficClass::ALL.iter().zip(e.shed) {
        s.push_str(&format!(", \"shed_{}\": {shed}", class.label().replace('-', "_")));
    }
    s.push_str(&format!(
        ", \"steals\": {}, \"power_w\": {}, \"mac_occupancy\": {}, \"token_wait_cycles\": {}",
        e.steals,
        num(e.power_w),
        num(e.mac_occupancy),
        num(e.token_wait_cycles)
    ));
    s.push_str(&format!(
        ", \"mac_occupancy_by_pkg\": [{}], \"token_wait_by_pkg\": [{}] }}",
        num_list(&e.mac_occupancy_by_pkg),
        num_list(&e.token_wait_by_pkg)
    ));
    s
}

/// One SLO raise/clear event as a single-line JSON object — shared by
/// the buffered `slo.events` array and the streamed `slo_event` lines.
fn slo_event_json(e: &SloEvent) -> String {
    format!(
        "{{ \"epoch\": {}, \"cycle\": {}, \"class\": \"{}\", \"window\": \"{}\", \
         \"kind\": \"{}\", \"burn_rate\": {} }}",
        e.epoch,
        num(e.cycle),
        e.class.label(),
        e.window.label(),
        e.kind.label(),
        num(e.burn_rate)
    )
}

/// Serialize the metrics registry (plus the always-on attribution sums
/// and, optionally, the process-wide cost-memo counters) as JSON.
///
/// `memo` is `None` when the caller needs cross-run comparability (the
/// determinism harness): the memo counters are process-global, so two
/// runs in one process see different cumulative values.
pub fn metrics_json(
    t: &Telemetry,
    attr: &PhaseTotals,
    class_attr: Option<&[PhaseTotals; NUM_CLASSES]>,
    memo: Option<MemoStats>,
) -> String {
    metrics_json_impl(t, attr, class_attr, memo, &[], &t.metrics.epochs)
}

/// [`metrics_json`] carrying quantile sketches: under `--bounded-stats`
/// the cluster's ε-bounded latency sketches ride along in a `sketches`
/// block at full sketch resolution, so `wienna report` can answer the
/// same quantiles the stats line printed instead of degrading to the
/// power-of-two histogram buckets.
pub fn metrics_json_with(
    t: &Telemetry,
    attr: &PhaseTotals,
    class_attr: Option<&[PhaseTotals; NUM_CLASSES]>,
    memo: Option<MemoStats>,
    sketches: &[NamedSketch<'_>],
) -> String {
    metrics_json_impl(t, attr, class_attr, memo, sketches, &t.metrics.epochs)
}

/// [`metrics_json`] with the `epochs` array left empty: the payload of
/// a stream's final `summary` line. [`stream_to_metrics_v1`] splices
/// the streamed epoch lines back into the empty slot to reproduce the
/// buffered artifact exactly.
pub fn metrics_json_summary(
    t: &Telemetry,
    attr: &PhaseTotals,
    class_attr: Option<&[PhaseTotals; NUM_CLASSES]>,
    memo: Option<MemoStats>,
) -> String {
    metrics_json_impl(t, attr, class_attr, memo, &[], &[])
}

/// [`metrics_json_summary`] carrying quantile sketches (see
/// [`metrics_json_with`]) — the bounded-mode stream summary, so the
/// reconstructed artifact stays byte-identical to the buffered one.
pub fn metrics_json_summary_with(
    t: &Telemetry,
    attr: &PhaseTotals,
    class_attr: Option<&[PhaseTotals; NUM_CLASSES]>,
    memo: Option<MemoStats>,
    sketches: &[NamedSketch<'_>],
) -> String {
    metrics_json_impl(t, attr, class_attr, memo, sketches, &[])
}

/// One sketch as a single-line JSON object. Values were recorded in
/// cycles; `scale` is the cycles→ms factor consumers multiply quantiles
/// by, so the on-disk buckets stay integer-exact (`(key, count)` pairs
/// straight out of [`QuantileSketch::buckets`]). The sentinel buckets
/// travel as separate `zero`/`inf` counts — their `i64::MIN`/`MAX` keys
/// are not exactly representable as JSON doubles.
fn sketch_json(name: &str, sk: &QuantileSketch) -> String {
    let mut s = format!(
        "{{ \"name\": \"{name}\", \"sub_bits\": {}, \"eps\": {}, \"scale\": {}, \
         \"count\": {}, \"sum\": {}, \"max\": {}, \"zero\": {}, \"inf\": {}, \"buckets\": [",
        sk.sub_bits(),
        num(sk.relative_error()),
        num(cycles_to_ms(1.0)),
        sk.count(),
        num(sk.sum()),
        num(sk.max()),
        sk.zero_count(),
        sk.inf_count(),
    );
    let finite: Vec<(i64, u64)> =
        sk.buckets().filter(|&(k, _)| k != i64::MIN && k != i64::MAX).collect();
    for (j, (k, c)) in finite.iter().enumerate() {
        s.push_str(&format!("[{k}, {c}]"));
        if j + 1 < finite.len() {
            s.push_str(", ");
        }
    }
    s.push_str("] }");
    s
}

fn metrics_json_impl(
    t: &Telemetry,
    attr: &PhaseTotals,
    class_attr: Option<&[PhaseTotals; NUM_CLASSES]>,
    memo: Option<MemoStats>,
    sketches: &[NamedSketch<'_>],
    epochs: &[EpochSample],
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"wienna-metrics-v1\",\n");
    s.push_str(&format!("  \"requests\": {},\n", attr.requests));
    s.push_str(&frac_fields("  ", attr));
    // NaN-safe: an empty run (NaN fractions) never alarms.
    let dist = attr.fractions()[1];
    let alarm = dist.is_finite() && dist >= DIST_ALARM_FRAC;
    s.push_str(&format!("  \"dist_alarm\": {alarm},\n"));
    s.push_str("  \"per_class\": [\n");
    if let Some(by_class) = class_attr {
        for (i, class) in TrafficClass::ALL.iter().enumerate() {
            let a = &by_class[class.index()];
            let mut line = format!(
                "    {{ \"class\": \"{}\", \"requests\": {}, ",
                class.label(),
                a.requests
            );
            let f = a.fractions();
            for (j, (name, v)) in super::profile::PHASES.iter().zip(f).enumerate() {
                line.push_str(&format!("\"{name}_frac\": {}", num(v)));
                if j + 1 < super::profile::PHASES.len() {
                    line.push_str(", ");
                }
            }
            line.push_str(" }");
            if i + 1 < TrafficClass::ALL.len() {
                line.push(',');
            }
            s.push_str(&line);
            s.push('\n');
        }
    }
    s.push_str("  ],\n");
    s.push_str("  \"histograms\": [\n");
    let hists = t.metrics.histograms();
    for (i, (name, h)) in hists.iter().enumerate() {
        s.push_str(&format!(
            "    {{ \"name\": \"{name}\", \"count\": {}, \"sum\": {}, \"buckets\": [",
            h.count,
            num(h.sum)
        ));
        for (j, (exp, n)) in h.buckets.iter().enumerate() {
            // The sentinel bucket (zero / negative / NaN samples) keys
            // on a JSON-unfriendly i32::MIN; emit it as null.
            let exp_s =
                if *exp == i32::MIN { "null".to_string() } else { format!("{exp}") };
            s.push_str(&format!("{{ \"exp\": {exp_s}, \"count\": {n} }}"));
            if j + 1 < h.buckets.len() {
                s.push_str(", ");
            }
        }
        s.push_str("] }");
        if i + 1 < hists.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ],\n");
    s.push_str("  \"sketches\": [\n");
    for (i, (name, sk)) in sketches.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&sketch_json(name, sk));
        if i + 1 < sketches.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ],\n");
    s.push_str("  \"epochs\": [\n");
    for (i, e) in epochs.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&epoch_json(e));
        if i + 1 < epochs.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ],\n");
    // The burn-rate monitor's verdict: raise/clear counts plus the full
    // event timeline with exact cycles. The opening line carries the
    // scalar fields so the only 4-space-indented lines in this block
    // are the event objects (the schema golden keys on that shape).
    let raised = t.metrics.slo_events.iter().filter(|e| e.kind == SloEventKind::Raise).count();
    let cleared = t.metrics.slo_events.len() - raised;
    s.push_str(&format!(
        "  \"slo\": {{ \"alerts_raised\": {raised}, \"alerts_cleared\": {cleared}, \"events\": [\n"
    ));
    for (i, e) in t.metrics.slo_events.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&slo_event_json(e));
        if i + 1 < t.metrics.slo_events.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ] },\n");
    match memo {
        Some(m) => {
            s.push_str("  \"memo\": {\n");
            s.push_str(&format!("    \"hits\": {},\n", m.hits));
            s.push_str(&format!("    \"misses\": {},\n", m.misses));
            s.push_str(&format!("    \"entries\": {},\n", m.entries));
            s.push_str(&format!("    \"evictions\": {},\n", m.evictions));
            s.push_str(&format!("    \"capacity\": {},\n", m.capacity));
            s.push_str(&format!("    \"hit_rate\": {}\n", num(m.hit_rate())));
            s.push_str("  }\n");
        }
        None => s.push_str("  \"memo\": null\n"),
    }
    s.push('}');
    s.push('\n');
    s
}

fn escape_json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 16);
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn unescape_json_string(s: &str) -> Option<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next()? {
                '\\' => out.push('\\'),
                '"' => out.push('"'),
                'n' => out.push('\n'),
                _ => return None,
            }
        } else {
            out.push(c);
        }
    }
    Some(out)
}

/// Incremental `wienna-metrics-stream-v1` JSONL writer.
///
/// Bounded-memory counterpart of buffering the run and calling
/// [`metrics_json`] at the end: the header goes out on construction,
/// [`MetricsStreamWriter::write_epoch`] appends each barrier's sample
/// the moment it is taken (only ever called single-threaded, at the
/// epoch barrier), [`MetricsStreamWriter::write_slo_event`] appends
/// burn-rate raises/clears as they fire, and the caller seals the
/// artifact with [`MetricsStreamWriter::write_summary`]. I/O errors are
/// deferred — the simulation never unwinds mid-epoch over a full disk —
/// and surfaced by [`MetricsStreamWriter::finish`].
pub struct MetricsStreamWriter<'a> {
    w: &'a mut dyn std::io::Write,
    err: Option<std::io::Error>,
}

impl<'a> MetricsStreamWriter<'a> {
    /// Wrap a sink and emit the schema header line.
    pub fn new(w: &'a mut dyn std::io::Write) -> Self {
        let mut s = MetricsStreamWriter { w, err: None };
        s.put(&format!("{{\"schema\": \"{METRICS_STREAM_SCHEMA}\"}}"));
        s
    }

    fn put(&mut self, line: &str) {
        if self.err.is_some() {
            return;
        }
        let r = self.w.write_all(line.as_bytes()).and_then(|()| self.w.write_all(b"\n"));
        if let Err(e) = r {
            self.err = Some(e);
        }
    }

    /// Append one epoch sample (exactly the buffered export's line).
    pub fn write_epoch(&mut self, e: &EpochSample) {
        self.put(&format!("{{\"epoch_sample\": {}}}", epoch_json(e)));
    }

    /// Append one SLO raise/clear event as it fires.
    pub fn write_slo_event(&mut self, e: &SloEvent) {
        self.put(&format!("{{\"slo_event\": {}}}", slo_event_json(e)));
    }

    /// Seal the artifact: the buffered metrics JSON with an empty
    /// epochs array ([`metrics_json_summary`]), JSON-string-escaped.
    pub fn write_summary(&mut self, summary: &str) {
        self.put(&format!("{{\"summary\": \"{}\"}}", escape_json_string(summary)));
    }

    /// Surface the first deferred I/O error, if any.
    pub fn finish(self) -> std::io::Result<()> {
        match self.err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Non-blocking, bounded, line-buffered adapter for live stream export.
///
/// Wraps a sink in non-blocking mode (a `TcpStream` after
/// `set_nonblocking(true)`) so the epoch barrier can emit
/// `wienna-metrics-stream-v1` lines without ever waiting on the
/// consumer: a slow or dead dashboard must not stall the simulation or
/// perturb its determinism. Bytes accumulate until a full line (`'\n'`)
/// forms, whole lines park in a bounded backlog, and every write
/// opportunistically drains until the first `WouldBlock`. When the
/// backlog would exceed `cap_bytes`, the *oldest* queued lines are
/// dropped and counted — a live consumer wants fresh epochs, not stale
/// ones — but never a partially-sent line, so the wire only ever
/// carries whole lines in order. A fatal I/O error kills the stream and
/// counts everything after it as dropped. [`NonBlockingLineSink::finish`]
/// grants a post-run grace period of short sleeps to flush the tail
/// (wall-clock is fine there: simulated time has already ended).
pub struct NonBlockingLineSink<W: Write> {
    inner: W,
    /// Partial line being accumulated (no `'\n'` seen yet).
    line: Vec<u8>,
    /// Line currently going out on the wire, possibly partially sent.
    inflight: Vec<u8>,
    sent: usize,
    backlog: VecDeque<Vec<u8>>,
    backlog_bytes: usize,
    cap_bytes: usize,
    dropped: u64,
    dead: bool,
}

impl<W: Write> NonBlockingLineSink<W> {
    /// Wrap `inner` with a backlog bounded at `cap_bytes`. A single
    /// line larger than the cap (the stream's `summary` line can be)
    /// is still kept — the bound applies when more than one line waits.
    pub fn new(inner: W, cap_bytes: usize) -> Self {
        NonBlockingLineSink {
            inner,
            line: Vec::new(),
            inflight: Vec::new(),
            sent: 0,
            backlog: VecDeque::new(),
            backlog_bytes: 0,
            cap_bytes,
            dropped: 0,
            dead: false,
        }
    }

    /// Lines dropped so far (backpressure overflow or a dead sink).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    fn push_line(&mut self, line: Vec<u8>) {
        if self.dead {
            self.dropped += 1;
            return;
        }
        self.backlog_bytes += line.len();
        self.backlog.push_back(line);
        while self.backlog_bytes > self.cap_bytes && self.backlog.len() > 1 {
            let old = self.backlog.pop_front().expect("len > 1");
            self.backlog_bytes -= old.len();
            self.dropped += 1;
        }
    }

    fn fail(&mut self) {
        self.dead = true;
        self.dropped += self.backlog.len() as u64;
        if !self.inflight.is_empty() {
            self.dropped += 1;
        }
        self.backlog.clear();
        self.backlog_bytes = 0;
        self.inflight.clear();
        self.sent = 0;
    }

    fn try_drain(&mut self) {
        if self.dead {
            return;
        }
        loop {
            if self.inflight.is_empty() {
                match self.backlog.pop_front() {
                    Some(l) => {
                        self.backlog_bytes -= l.len();
                        self.inflight = l;
                        self.sent = 0;
                    }
                    None => return,
                }
            }
            match self.inner.write(&self.inflight[self.sent..]) {
                Ok(0) => {
                    self.fail();
                    return;
                }
                Ok(n) => {
                    self.sent += n;
                    if self.sent == self.inflight.len() {
                        self.inflight.clear();
                        self.sent = 0;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.fail();
                    return;
                }
            }
        }
    }

    /// Post-run drain: keep retrying (5 ms sleeps) until the backlog
    /// empties, the sink dies, or `deadline` elapses — whatever is
    /// still queued then counts as dropped. Returns the sink and the
    /// total dropped-line count.
    pub fn finish(mut self, deadline: std::time::Duration) -> (W, u64) {
        if !self.line.is_empty() {
            // A trailing partial line can never be completed now; the
            // whole-lines-only contract says it must not hit the wire.
            self.line.clear();
            self.dropped += 1;
        }
        let start = std::time::Instant::now();
        loop {
            self.try_drain();
            if self.dead || (self.inflight.is_empty() && self.backlog.is_empty()) {
                break;
            }
            if start.elapsed() >= deadline {
                self.dropped += self.backlog.len() as u64;
                if !self.inflight.is_empty() {
                    self.dropped += 1;
                }
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let _ = self.inner.flush();
        (self.inner, self.dropped)
    }
}

impl<W: Write> Write for NonBlockingLineSink<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        for &b in buf {
            self.line.push(b);
            if b == b'\n' {
                let line = std::mem::take(&mut self.line);
                self.push_line(line);
            }
        }
        self.try_drain();
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.try_drain();
        Ok(())
    }
}

/// Reconstruct the buffered `wienna-metrics-v1` artifact from a
/// complete `wienna-metrics-stream-v1` stream: unescape the summary
/// line and splice the streamed epoch lines into its empty `epochs`
/// slot. Returns `None` on a malformed or truncated stream (wrong
/// header, unknown line shape, or no summary). The result is
/// byte-identical to what [`metrics_json`] would have produced — both
/// sides render each epoch through the same single-line serializer.
pub fn stream_to_metrics_v1(stream: &str) -> Option<String> {
    let mut lines = stream.lines();
    let header = lines.next()?;
    if header != format!("{{\"schema\": \"{METRICS_STREAM_SCHEMA}\"}}") {
        return None;
    }
    let mut epochs: Vec<&str> = Vec::new();
    let mut summary: Option<String> = None;
    for line in lines {
        if let Some(rest) = line.strip_prefix("{\"epoch_sample\": ") {
            epochs.push(rest.strip_suffix('}')?);
        } else if let Some(rest) = line.strip_prefix("{\"summary\": \"") {
            summary = Some(unescape_json_string(rest.strip_suffix("\"}")?)?);
        } else if line.starts_with("{\"slo_event\": ") || line.is_empty() {
            // Event lines are for live consumers; the summary already
            // carries the full slo block. Blank lines are tolerated.
        } else {
            return None;
        }
    }
    let summary = summary?;
    let empty_slot = "  \"epochs\": [\n  ],\n";
    let idx = summary.find(empty_slot)?;
    let mut spliced = String::from("  \"epochs\": [\n");
    for (i, e) in epochs.iter().enumerate() {
        spliced.push_str("    ");
        spliced.push_str(e);
        if i + 1 < epochs.len() {
            spliced.push(',');
        }
        spliced.push('\n');
    }
    spliced.push_str("  ],\n");
    let mut out = String::with_capacity(summary.len() + spliced.len());
    out.push_str(&summary[..idx]);
    out.push_str(&spliced);
    out.push_str(&summary[idx + empty_slot.len()..]);
    Some(out)
}

fn class_json(class: Option<TrafficClass>) -> String {
    match class {
        Some(c) => format!("\"{}\"", c.label()),
        None => "null".to_string(),
    }
}

/// Serialize the span log + epoch series in Chrome trace-event format.
/// Load the file at <https://ui.perfetto.dev> or `chrome://tracing`.
pub fn chrome_trace(t: &Telemetry) -> String {
    let log = &t.log;
    let mut events: Vec<String> = Vec::new();

    // "M" process metadata: one row per shard that emitted anything.
    let max_shard = log
        .spans
        .iter()
        .map(|s| s.shard)
        .chain(log.sheds.iter().map(|s| s.shard))
        .chain(log.preemptions.iter().map(|p| p.shard))
        .chain(log.flows.iter().flat_map(|f| [f.from_shard, f.to_shard]))
        .max();
    if let Some(max_shard) = max_shard {
        for shard in 0..=max_shard {
            events.push(format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{shard},\"tid\":0,\
                 \"args\":{{\"name\":\"shard {shard}\"}}}}"
            ));
        }
    }

    // "X" complete slices: one per request span, on the package's row.
    for s in &log.spans {
        events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"request\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\
             \"ts\":{},\"dur\":{},\"args\":{{\"id\":{},\"batch\":{},\"class\":{},\
             \"queue_ms\":{}}}}}",
            s.kind.label(),
            s.shard,
            s.package,
            num(ts_us(s.dispatched)),
            num(ts_us(s.completed - s.dispatched)),
            s.id,
            s.batch,
            class_json(s.class),
            num(cycles_to_ms(s.phases.queue)),
        ));
    }

    // "i" instants: sheds and preemptions.
    for s in &log.sheds {
        events.push(format!(
            "{{\"name\":\"shed {}\",\"cat\":\"admission\",\"ph\":\"i\",\"pid\":{},\"tid\":0,\
             \"ts\":{},\"s\":\"p\",\"args\":{{\"id\":{},\"model\":\"{}\",\"class\":{}}}}}",
            s.reason.label(),
            s.shard,
            num(ts_us(s.cycle)),
            s.id,
            s.kind.label(),
            class_json(s.class),
        ));
    }
    for p in &log.preemptions {
        events.push(format!(
            "{{\"name\":\"preempt\",\"cat\":\"scheduler\",\"ph\":\"i\",\"pid\":{},\"tid\":{},\
             \"ts\":{},\"s\":\"p\",\"args\":{{\"batch\":{}}}}}",
            p.shard,
            p.package,
            num(ts_us(p.cycle)),
            p.batch,
        ));
    }

    // "s"/"f" flow pairs: one arrow per cross-shard hand-off (steal or
    // failover re-route), from the donor's row to the victim's. Chrome
    // binds the pair by `(cat, name, id)`; a request re-routed again
    // later simply extends the chain.
    for f in &log.flows {
        let ts = num(ts_us(f.cycle));
        events.push(format!(
            "{{\"name\":\"handoff\",\"cat\":\"flow\",\"ph\":\"s\",\"pid\":{},\"tid\":0,\
             \"ts\":{ts},\"id\":{},\"args\":{{\"class\":\"{}\"}}}}",
            f.from_shard,
            f.id,
            f.class.label(),
        ));
        events.push(format!(
            "{{\"name\":\"handoff\",\"cat\":\"flow\",\"ph\":\"f\",\"bp\":\"e\",\"pid\":{},\
             \"tid\":0,\"ts\":{ts},\"id\":{},\"args\":{{\"class\":\"{}\"}}}}",
            f.to_shard,
            f.id,
            f.class.label(),
        ));
    }

    // "C" counters: the epoch gauges, one track each, pinned to pid 0.
    for e in &t.metrics.epochs {
        let ts = num(ts_us(e.cycle));
        for (name, v) in [
            ("queued", e.queued as f64),
            ("in_flight_batches", e.in_flight_batches as f64),
            ("steals", e.steals as f64),
            ("power_w", e.power_w),
            ("mac_occupancy", e.mac_occupancy),
            ("token_wait_cycles", e.token_wait_cycles),
        ] {
            events.push(format!(
                "{{\"name\":\"{name}\",\"ph\":\"C\",\"pid\":0,\"tid\":0,\"ts\":{ts},\
                 \"args\":{{\"{name}\":{}}}}}",
                num(v)
            ));
        }
    }

    let mut s = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    s.push_str(&events.join(",\n"));
    s.push_str("\n]}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::metrics::EpochSample;
    use crate::telemetry::slo::{SloEvent, SloEventKind, SloWindow};
    use crate::telemetry::span::{FlowRecord, PreemptSpan, ShedSpan, SpanRecord};
    use crate::telemetry::PhaseBreakdown;
    use crate::cluster::ShedReason;
    use crate::serve::ModelKind;

    fn sample_telemetry() -> Telemetry {
        let mut t = Telemetry::default();
        t.log.spans.push(SpanRecord {
            id: 7,
            kind: ModelKind::TinyCnn,
            class: Some(TrafficClass::Interactive),
            shard: 1,
            package: 0,
            batch: 2,
            arrival: 0.0,
            dispatched: 1000.0,
            completed: 3000.0,
            phases: PhaseBreakdown { queue: 1000.0, ..Default::default() },
        });
        t.log.sheds.push(ShedSpan {
            id: 9,
            kind: ModelKind::Mlp,
            class: None,
            shard: 0,
            arrival: 10.0,
            cycle: 20.0,
            reason: ShedReason::QueueFull,
        });
        t.log.preemptions.push(PreemptSpan { cycle: 50.0, shard: 1, package: 1, batch: 4 });
        t.log.flows.push(FlowRecord {
            id: 42,
            class: TrafficClass::BestEffort,
            from_shard: 1,
            to_shard: 2,
            cycle: 2000.0,
        });
        t.metrics.epochs.push(EpochSample {
            epoch: 0,
            cycle: 4000.0,
            queued: 3,
            mac_occupancy_by_pkg: vec![0.25, 0.5],
            token_wait_by_pkg: vec![0.0, 12.0],
            ..Default::default()
        });
        t.metrics.epochs.push(EpochSample { epoch: 1, cycle: 8000.0, ..Default::default() });
        t.metrics.slo_events.push(SloEvent {
            epoch: 1,
            cycle: 8000.0,
            class: TrafficClass::Interactive,
            window: SloWindow::Fast,
            kind: SloEventKind::Raise,
            burn_rate: 9.5,
        });
        t.metrics.latency_ms.record(2.5);
        t
    }

    #[test]
    fn trace_is_json_shaped_and_covers_all_event_kinds() {
        let s = chrome_trace(&sample_telemetry());
        assert!(s.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n"));
        assert!(s.ends_with("\n]}\n"));
        for needle in [
            "\"ph\":\"M\"",
            "\"ph\":\"X\"",
            "\"ph\":\"i\"",
            "\"ph\":\"C\"",
            "\"ph\":\"s\"",
            "\"ph\":\"f\"",
            "shed queue-full",
        ] {
            assert!(s.contains(needle), "missing {needle} in trace");
        }
        // Process metadata covers shards 0..=2 (shard 2 only received a
        // flow hand-off — it still gets a named row).
        assert!(s.contains("\"name\":\"shard 0\""));
        assert!(s.contains("\"name\":\"shard 2\""));
        // The flow pair binds donor to victim through one id.
        assert!(s.contains("\"cat\":\"flow\",\"ph\":\"s\",\"pid\":1,"));
        assert!(s.contains("\"ph\":\"f\",\"bp\":\"e\",\"pid\":2,"));
        assert_eq!(s.matches("\"id\":42").count(), 2, "both flow ends carry the request id");
    }

    #[test]
    fn metrics_json_emits_null_for_empty_fraction_and_elided_memo() {
        let t = Telemetry::default();
        let s = metrics_json(&t, &PhaseTotals::default(), None, None);
        assert!(s.contains("\"queue_frac\": null"));
        assert!(s.contains("\"memo\": null"));
        assert!(s.contains("\"schema\": \"wienna-metrics-v1\""));
        assert!(s.contains("\"dist_alarm\": false"), "an empty run never alarms");
        assert!(
            s.contains("\"slo\": { \"alerts_raised\": 0, \"alerts_cleared\": 0, \"events\": ["),
            "the slo block is present even when no alert ever fired"
        );
    }

    #[test]
    fn dist_alarm_trips_on_dist_heavy_attribution() {
        let t = Telemetry::default();
        let mut attr = PhaseTotals::default();
        attr.requests = 1;
        attr.dist = 60.0;
        attr.compute = 40.0;
        let s = metrics_json(&t, &attr, None, None);
        assert!(s.contains("\"dist_alarm\": true"), "60% dist must trip the {DIST_ALARM_FRAC} alarm");
    }

    #[test]
    fn metrics_json_includes_memo_when_provided() {
        let t = sample_telemetry();
        let m = MemoStats { hits: 10, misses: 2, entries: 2, evictions: 0, capacity: 64 };
        let s = metrics_json(&t, &PhaseTotals::default(), None, Some(m));
        assert!(s.contains("\"hits\": 10"));
        assert!(s.contains("\"hit_rate\": "));
        assert!(s.contains("\"buckets\": [{ \"exp\": 1, \"count\": 1 }]"));
    }

    #[test]
    fn epoch_line_carries_the_per_package_gauges_and_slo_events_render() {
        let t = sample_telemetry();
        let s = metrics_json(&t, &PhaseTotals::default(), None, None);
        assert!(s.contains("\"mac_occupancy_by_pkg\": [0.25, 0.5]"));
        assert!(s.contains("\"token_wait_by_pkg\": [0, 12]"));
        assert!(s.contains("\"slo\": { \"alerts_raised\": 1, \"alerts_cleared\": 0, \"events\": ["));
        assert!(s.contains(
            "{ \"epoch\": 1, \"cycle\": 8000, \"class\": \"interactive\", \
             \"window\": \"fast\", \"kind\": \"raise\", \"burn_rate\": 9.5 }"
        ));
    }

    #[test]
    fn stream_reconstructs_the_buffered_artifact_byte_for_byte() {
        let t = sample_telemetry();
        let attr = PhaseTotals::default();
        let buffered = metrics_json(&t, &attr, None, None);

        let mut sink: Vec<u8> = Vec::new();
        let mut w = MetricsStreamWriter::new(&mut sink);
        for e in &t.metrics.epochs {
            w.write_epoch(e);
        }
        for ev in &t.metrics.slo_events {
            w.write_slo_event(ev);
        }
        let summary = metrics_json_summary(&t, &attr, None, None);
        w.write_summary(&summary);
        w.finish().expect("Vec sink cannot fail");

        let stream = String::from_utf8(sink).expect("stream is UTF-8");
        assert!(stream.starts_with("{\"schema\": \"wienna-metrics-stream-v1\"}\n"));
        assert!(stream.contains("{\"epoch_sample\": { \"epoch\": 0,"));
        assert!(stream.contains("{\"slo_event\": { \"epoch\": 1,"));
        let reconstructed = stream_to_metrics_v1(&stream).expect("well-formed stream");
        assert_eq!(reconstructed, buffered, "splice must be byte-exact");
    }

    #[test]
    fn stream_reconstruction_rejects_malformed_streams() {
        assert_eq!(stream_to_metrics_v1(""), None, "empty stream");
        assert_eq!(
            stream_to_metrics_v1("{\"schema\": \"wienna-metrics-v1\"}\n"),
            None,
            "wrong schema header"
        );
        let headless = "{\"epoch_sample\": { \"epoch\": 0 }}\n";
        assert_eq!(stream_to_metrics_v1(headless), None, "missing header");
        let no_summary = "{\"schema\": \"wienna-metrics-stream-v1\"}\n\
                          {\"epoch_sample\": { \"epoch\": 0 }}\n";
        assert_eq!(stream_to_metrics_v1(no_summary), None, "truncated before summary");
        let junk = "{\"schema\": \"wienna-metrics-stream-v1\"}\nnot json\n";
        assert_eq!(stream_to_metrics_v1(junk), None, "unknown line shape");
    }

    #[test]
    fn string_escaping_round_trips_artifact_text() {
        let gnarly = "line one\n  \"quoted\" and a back\\slash\n";
        let escaped = escape_json_string(gnarly);
        assert!(!escaped.contains('\n'), "escaped text is single-line");
        assert_eq!(unescape_json_string(&escaped).as_deref(), Some(gnarly));
        assert_eq!(unescape_json_string("bad \\q escape"), None);
    }

    /// Scripted fake socket: each `write` consumes one step; an empty
    /// script accepts everything.
    enum Step {
        Accept,
        Partial(usize),
        WouldBlock,
        Broken,
    }

    struct ScriptedWriter {
        script: VecDeque<Step>,
        written: Vec<u8>,
    }

    impl ScriptedWriter {
        fn new(script: Vec<Step>) -> Self {
            ScriptedWriter { script: script.into(), written: Vec::new() }
        }
    }

    impl Write for ScriptedWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            match self.script.pop_front().unwrap_or(Step::Accept) {
                Step::Accept => {
                    self.written.extend_from_slice(buf);
                    Ok(buf.len())
                }
                Step::Partial(n) => {
                    let n = n.min(buf.len());
                    self.written.extend_from_slice(&buf[..n]);
                    Ok(n)
                }
                Step::WouldBlock => Err(io::Error::new(io::ErrorKind::WouldBlock, "full")),
                Step::Broken => Err(io::Error::new(io::ErrorKind::BrokenPipe, "gone")),
            }
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn nonblocking_sink_reassembles_lines_across_partial_writes() {
        let w = ScriptedWriter::new(vec![Step::Partial(2)]);
        let mut sink = NonBlockingLineSink::new(w, 1 << 20);
        sink.write_all(b"abc\n").expect("sink never errors");
        let (w, dropped) = sink.finish(std::time::Duration::from_millis(50));
        assert_eq!(w.written, b"abc\n");
        assert_eq!(dropped, 0);
    }

    #[test]
    fn nonblocking_sink_parks_lines_on_wouldblock_and_drains_in_order() {
        let w = ScriptedWriter::new(vec![Step::WouldBlock]);
        let mut sink = NonBlockingLineSink::new(w, 1 << 20);
        sink.write_all(b"one\n").expect("sink never errors");
        sink.write_all(b"two\n").expect("sink never errors");
        let (w, dropped) = sink.finish(std::time::Duration::from_millis(50));
        assert_eq!(w.written, b"one\ntwo\n", "order preserved across the stall");
        assert_eq!(dropped, 0);
    }

    #[test]
    fn nonblocking_sink_drops_oldest_lines_when_the_backlog_overflows() {
        // Every write stalls; lines are 3 bytes, the cap fits two.
        let w = ScriptedWriter::new((0..100).map(|_| Step::WouldBlock).collect());
        let mut sink = NonBlockingLineSink::new(w, 6);
        for l in [b"l1\n", b"l2\n", b"l3\n", b"l4\n", b"l5\n"] {
            sink.write_all(l).expect("sink never errors");
        }
        assert_eq!(sink.dropped(), 2, "l2 and l3 evicted oldest-first (l1 is in flight)");
        let (w, dropped) = sink.finish(std::time::Duration::ZERO);
        assert!(w.written.is_empty(), "nothing ever reached the wire");
        assert_eq!(dropped, 5, "the expired deadline counts the stranded tail");
    }

    #[test]
    fn nonblocking_sink_survives_a_dead_peer_and_counts_the_loss() {
        let w = ScriptedWriter::new(vec![Step::Broken]);
        let mut sink = NonBlockingLineSink::new(w, 1 << 20);
        sink.write_all(b"a\n").expect("a fatal sink error must not surface");
        sink.write_all(b"b\n").expect("a fatal sink error must not surface");
        let (w, dropped) = sink.finish(std::time::Duration::from_millis(50));
        assert!(w.written.is_empty());
        assert_eq!(dropped, 2, "every line after the break is accounted for");
    }
}
