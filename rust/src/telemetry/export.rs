//! Serializers: the metrics JSON and the Chrome trace-event export.
//!
//! Both are hand-rolled like `ClusterStats::to_json` — no JSON crate —
//! and deterministic: every number renders through `format!("{v}")`
//! (shortest round-trip), every collection iterates in a fixed order,
//! and non-finite values become `null`. The field names and their order
//! are pinned by `rust/testdata/telemetry_schema.golden`; update that
//! fixture only for a deliberate schema change.
//!
//! The trace export follows the Chrome trace-event format (the JSON
//! Perfetto and `chrome://tracing` load): `"X"` complete slices for
//! request spans, `"i"` instants for sheds/preemptions, `"s"`/`"f"`
//! flow pairs linking a cross-shard hand-off's donor enqueue to its
//! victim-side service, `"C"` counters for the per-epoch gauges, and
//! `"M"` process-name metadata per shard. Timestamps are microseconds
//! of simulated time.

use crate::cluster::{TrafficClass, NUM_CLASSES};
use crate::cost::memo::MemoStats;
use crate::serve::cycles_to_ms;

use super::profile::PhaseTotals;
use super::Telemetry;

fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Dist-phase blowup alarm threshold: when completed requests spend
/// this fraction (or more) of their end-to-end cycles in the `dist`
/// phase, the shared wireless medium is the bottleneck — expected under
/// injected contention (`wienna::fault`), a red flag otherwise. The
/// metrics JSON carries the verdict as `"dist_alarm"`.
pub const DIST_ALARM_FRAC: f64 = 0.4;

/// Simulated cycle → trace-event timestamp (µs).
fn ts_us(cycle: f64) -> f64 {
    cycles_to_ms(cycle) * 1000.0
}

fn frac_fields(indent: &str, t: &PhaseTotals) -> String {
    let f = t.fractions();
    let mut s = String::new();
    for (name, v) in super::profile::PHASES.iter().zip(f) {
        s.push_str(&format!("{indent}\"{name}_frac\": {},\n", num(v)));
    }
    s
}

/// Serialize the metrics registry (plus the always-on attribution sums
/// and, optionally, the process-wide cost-memo counters) as JSON.
///
/// `memo` is `None` when the caller needs cross-run comparability (the
/// determinism harness): the memo counters are process-global, so two
/// runs in one process see different cumulative values.
pub fn metrics_json(
    t: &Telemetry,
    attr: &PhaseTotals,
    class_attr: Option<&[PhaseTotals; NUM_CLASSES]>,
    memo: Option<MemoStats>,
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"wienna-metrics-v1\",\n");
    s.push_str(&format!("  \"requests\": {},\n", attr.requests));
    s.push_str(&frac_fields("  ", attr));
    // NaN-safe: an empty run (NaN fractions) never alarms.
    let dist = attr.fractions()[1];
    let alarm = dist.is_finite() && dist >= DIST_ALARM_FRAC;
    s.push_str(&format!("  \"dist_alarm\": {alarm},\n"));
    s.push_str("  \"per_class\": [\n");
    if let Some(by_class) = class_attr {
        for (i, class) in TrafficClass::ALL.iter().enumerate() {
            let a = &by_class[class.index()];
            let mut line = format!(
                "    {{ \"class\": \"{}\", \"requests\": {}, ",
                class.label(),
                a.requests
            );
            let f = a.fractions();
            for (j, (name, v)) in super::profile::PHASES.iter().zip(f).enumerate() {
                line.push_str(&format!("\"{name}_frac\": {}", num(v)));
                if j + 1 < super::profile::PHASES.len() {
                    line.push_str(", ");
                }
            }
            line.push_str(" }");
            if i + 1 < TrafficClass::ALL.len() {
                line.push(',');
            }
            s.push_str(&line);
            s.push('\n');
        }
    }
    s.push_str("  ],\n");
    s.push_str("  \"histograms\": [\n");
    let hists = t.metrics.histograms();
    for (i, (name, h)) in hists.iter().enumerate() {
        s.push_str(&format!(
            "    {{ \"name\": \"{name}\", \"count\": {}, \"sum\": {}, \"buckets\": [",
            h.count,
            num(h.sum)
        ));
        for (j, (exp, n)) in h.buckets.iter().enumerate() {
            // The sentinel bucket (zero / negative / NaN samples) keys
            // on a JSON-unfriendly i32::MIN; emit it as null.
            let exp_s =
                if *exp == i32::MIN { "null".to_string() } else { format!("{exp}") };
            s.push_str(&format!("{{ \"exp\": {exp_s}, \"count\": {n} }}"));
            if j + 1 < h.buckets.len() {
                s.push_str(", ");
            }
        }
        s.push_str("] }");
        if i + 1 < hists.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ],\n");
    s.push_str("  \"epochs\": [\n");
    for (i, e) in t.metrics.epochs.iter().enumerate() {
        s.push_str(&format!(
            "    {{ \"epoch\": {}, \"cycle\": {}, \"queued\": {}, \
             \"in_flight_batches\": {}, \"completed\": {}",
            e.epoch,
            num(e.cycle),
            e.queued,
            e.in_flight_batches,
            e.completed
        ));
        for (class, shed) in TrafficClass::ALL.iter().zip(e.shed) {
            s.push_str(&format!(", \"shed_{}\": {shed}", class.label().replace('-', "_")));
        }
        s.push_str(&format!(
            ", \"steals\": {}, \"power_w\": {}, \"mac_occupancy\": {}, \
             \"token_wait_cycles\": {} }}",
            e.steals,
            num(e.power_w),
            num(e.mac_occupancy),
            num(e.token_wait_cycles)
        ));
        if i + 1 < t.metrics.epochs.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ],\n");
    match memo {
        Some(m) => {
            s.push_str("  \"memo\": {\n");
            s.push_str(&format!("    \"hits\": {},\n", m.hits));
            s.push_str(&format!("    \"misses\": {},\n", m.misses));
            s.push_str(&format!("    \"entries\": {},\n", m.entries));
            s.push_str(&format!("    \"evictions\": {},\n", m.evictions));
            s.push_str(&format!("    \"capacity\": {},\n", m.capacity));
            s.push_str(&format!("    \"hit_rate\": {}\n", num(m.hit_rate())));
            s.push_str("  }\n");
        }
        None => s.push_str("  \"memo\": null\n"),
    }
    s.push('}');
    s.push('\n');
    s
}

fn class_json(class: Option<TrafficClass>) -> String {
    match class {
        Some(c) => format!("\"{}\"", c.label()),
        None => "null".to_string(),
    }
}

/// Serialize the span log + epoch series in Chrome trace-event format.
/// Load the file at <https://ui.perfetto.dev> or `chrome://tracing`.
pub fn chrome_trace(t: &Telemetry) -> String {
    let log = &t.log;
    let mut events: Vec<String> = Vec::new();

    // "M" process metadata: one row per shard that emitted anything.
    let max_shard = log
        .spans
        .iter()
        .map(|s| s.shard)
        .chain(log.sheds.iter().map(|s| s.shard))
        .chain(log.preemptions.iter().map(|p| p.shard))
        .chain(log.flows.iter().flat_map(|f| [f.from_shard, f.to_shard]))
        .max();
    if let Some(max_shard) = max_shard {
        for shard in 0..=max_shard {
            events.push(format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{shard},\"tid\":0,\
                 \"args\":{{\"name\":\"shard {shard}\"}}}}"
            ));
        }
    }

    // "X" complete slices: one per request span, on the package's row.
    for s in &log.spans {
        events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"request\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\
             \"ts\":{},\"dur\":{},\"args\":{{\"id\":{},\"batch\":{},\"class\":{},\
             \"queue_ms\":{}}}}}",
            s.kind.label(),
            s.shard,
            s.package,
            num(ts_us(s.dispatched)),
            num(ts_us(s.completed - s.dispatched)),
            s.id,
            s.batch,
            class_json(s.class),
            num(cycles_to_ms(s.phases.queue)),
        ));
    }

    // "i" instants: sheds and preemptions.
    for s in &log.sheds {
        events.push(format!(
            "{{\"name\":\"shed {}\",\"cat\":\"admission\",\"ph\":\"i\",\"pid\":{},\"tid\":0,\
             \"ts\":{},\"s\":\"p\",\"args\":{{\"id\":{},\"model\":\"{}\",\"class\":{}}}}}",
            s.reason.label(),
            s.shard,
            num(ts_us(s.cycle)),
            s.id,
            s.kind.label(),
            class_json(s.class),
        ));
    }
    for p in &log.preemptions {
        events.push(format!(
            "{{\"name\":\"preempt\",\"cat\":\"scheduler\",\"ph\":\"i\",\"pid\":{},\"tid\":{},\
             \"ts\":{},\"s\":\"p\",\"args\":{{\"batch\":{}}}}}",
            p.shard,
            p.package,
            num(ts_us(p.cycle)),
            p.batch,
        ));
    }

    // "s"/"f" flow pairs: one arrow per cross-shard hand-off (steal or
    // failover re-route), from the donor's row to the victim's. Chrome
    // binds the pair by `(cat, name, id)`; a request re-routed again
    // later simply extends the chain.
    for f in &log.flows {
        let ts = num(ts_us(f.cycle));
        events.push(format!(
            "{{\"name\":\"handoff\",\"cat\":\"flow\",\"ph\":\"s\",\"pid\":{},\"tid\":0,\
             \"ts\":{ts},\"id\":{},\"args\":{{\"class\":\"{}\"}}}}",
            f.from_shard,
            f.id,
            f.class.label(),
        ));
        events.push(format!(
            "{{\"name\":\"handoff\",\"cat\":\"flow\",\"ph\":\"f\",\"bp\":\"e\",\"pid\":{},\
             \"tid\":0,\"ts\":{ts},\"id\":{},\"args\":{{\"class\":\"{}\"}}}}",
            f.to_shard,
            f.id,
            f.class.label(),
        ));
    }

    // "C" counters: the epoch gauges, one track each, pinned to pid 0.
    for e in &t.metrics.epochs {
        let ts = num(ts_us(e.cycle));
        for (name, v) in [
            ("queued", e.queued as f64),
            ("in_flight_batches", e.in_flight_batches as f64),
            ("steals", e.steals as f64),
            ("power_w", e.power_w),
            ("mac_occupancy", e.mac_occupancy),
            ("token_wait_cycles", e.token_wait_cycles),
        ] {
            events.push(format!(
                "{{\"name\":\"{name}\",\"ph\":\"C\",\"pid\":0,\"tid\":0,\"ts\":{ts},\
                 \"args\":{{\"{name}\":{}}}}}",
                num(v)
            ));
        }
    }

    let mut s = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    s.push_str(&events.join(",\n"));
    s.push_str("\n]}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::metrics::EpochSample;
    use crate::telemetry::span::{FlowRecord, PreemptSpan, ShedSpan, SpanRecord};
    use crate::telemetry::PhaseBreakdown;
    use crate::cluster::ShedReason;
    use crate::serve::ModelKind;

    fn sample_telemetry() -> Telemetry {
        let mut t = Telemetry::default();
        t.log.spans.push(SpanRecord {
            id: 7,
            kind: ModelKind::TinyCnn,
            class: Some(TrafficClass::Interactive),
            shard: 1,
            package: 0,
            batch: 2,
            arrival: 0.0,
            dispatched: 1000.0,
            completed: 3000.0,
            phases: PhaseBreakdown { queue: 1000.0, ..Default::default() },
        });
        t.log.sheds.push(ShedSpan {
            id: 9,
            kind: ModelKind::Mlp,
            class: None,
            shard: 0,
            arrival: 10.0,
            cycle: 20.0,
            reason: ShedReason::QueueFull,
        });
        t.log.preemptions.push(PreemptSpan { cycle: 50.0, shard: 1, package: 1, batch: 4 });
        t.log.flows.push(FlowRecord {
            id: 42,
            class: TrafficClass::BestEffort,
            from_shard: 1,
            to_shard: 2,
            cycle: 2000.0,
        });
        t.metrics.epochs.push(EpochSample { epoch: 0, cycle: 4000.0, queued: 3, ..Default::default() });
        t.metrics.latency_ms.record(2.5);
        t
    }

    #[test]
    fn trace_is_json_shaped_and_covers_all_event_kinds() {
        let s = chrome_trace(&sample_telemetry());
        assert!(s.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n"));
        assert!(s.ends_with("\n]}\n"));
        for needle in [
            "\"ph\":\"M\"",
            "\"ph\":\"X\"",
            "\"ph\":\"i\"",
            "\"ph\":\"C\"",
            "\"ph\":\"s\"",
            "\"ph\":\"f\"",
            "shed queue-full",
        ] {
            assert!(s.contains(needle), "missing {needle} in trace");
        }
        // Process metadata covers shards 0..=2 (shard 2 only received a
        // flow hand-off — it still gets a named row).
        assert!(s.contains("\"name\":\"shard 0\""));
        assert!(s.contains("\"name\":\"shard 2\""));
        // The flow pair binds donor to victim through one id.
        assert!(s.contains("\"cat\":\"flow\",\"ph\":\"s\",\"pid\":1,"));
        assert!(s.contains("\"ph\":\"f\",\"bp\":\"e\",\"pid\":2,"));
        assert_eq!(s.matches("\"id\":42").count(), 2, "both flow ends carry the request id");
    }

    #[test]
    fn metrics_json_emits_null_for_empty_fraction_and_elided_memo() {
        let t = Telemetry::default();
        let s = metrics_json(&t, &PhaseTotals::default(), None, None);
        assert!(s.contains("\"queue_frac\": null"));
        assert!(s.contains("\"memo\": null"));
        assert!(s.contains("\"schema\": \"wienna-metrics-v1\""));
        assert!(s.contains("\"dist_alarm\": false"), "an empty run never alarms");
    }

    #[test]
    fn dist_alarm_trips_on_dist_heavy_attribution() {
        let t = Telemetry::default();
        let mut attr = PhaseTotals::default();
        attr.requests = 1;
        attr.dist = 60.0;
        attr.compute = 40.0;
        let s = metrics_json(&t, &attr, None, None);
        assert!(s.contains("\"dist_alarm\": true"), "60% dist must trip the {DIST_ALARM_FRAC} alarm");
    }

    #[test]
    fn metrics_json_includes_memo_when_provided() {
        let t = sample_telemetry();
        let m = MemoStats { hits: 10, misses: 2, entries: 2, evictions: 0, capacity: 64 };
        let s = metrics_json(&t, &PhaseTotals::default(), None, Some(m));
        assert!(s.contains("\"hits\": 10"));
        assert!(s.contains("\"hit_rate\": "));
        assert!(s.contains("\"buckets\": [{ \"exp\": 1, \"count\": 1 }]"));
    }
}
