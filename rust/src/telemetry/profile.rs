//! Cycle-attribution profiling: where did a request's cycles go?
//!
//! Every completed request's end-to-end latency is split into five
//! phases — queueing, NoP distribution, chiplet compute, collection-mesh
//! gather, and DVFS cap-throttle stretch — using only quantities the
//! event loop already has in hand at completion time (the dispatch
//! timestamps and the batch's [`BatchCost`] plane-busy breakdown). The
//! split is cheap enough to stay **always on**: ~10 flops per request,
//! no allocation, accumulated into [`PhaseTotals`] sums that surface as
//! `*_frac` fields in the stats JSON.
//!
//! [`BatchCost`]: crate::serve::BatchCost

use crate::serve::BatchCost;

/// Phase names, in canonical emission order. Keep in sync with
/// [`PhaseBreakdown`] / [`PhaseTotals::fractions`].
pub const PHASES: [&str; 5] = ["queue", "dist", "compute", "collect", "throttle"];

/// One request's end-to-end latency split into attribution phases
/// (cycles). Built by [`PhaseBreakdown::attribute`]; all phases are
/// non-negative and sum to the end-to-end latency (up to float
/// rounding — the conservation property test pins this at 1e-9
/// relative).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseBreakdown {
    /// Cycles between arrival and batch dispatch (admission queue wait,
    /// including any aborted-then-requeued time for preempted requests
    /// and the barrier delay for stolen ones).
    pub queue: f64,
    /// Cycles attributed to the NoP distribution plane.
    pub dist: f64,
    /// Cycles attributed to the chiplets' compute arrays.
    pub compute: f64,
    /// Cycles attributed to the wired collection mesh.
    pub collect: f64,
    /// Extra service cycles added by DVFS cap-throttle stretch (exactly
    /// zero at nominal frequency).
    pub throttle: f64,
}

impl PhaseBreakdown {
    /// Split `completed - arrival` into phases.
    ///
    /// * `queue` is the dispatch wait, straight from timestamps.
    /// * The *nominal* service time (`cost.latency`) is apportioned to
    ///   dist/compute/collect pro rata to the planes' busy cycles, with
    ///   `collect` taking the exact remainder so the three sum to
    ///   `cost.latency` by construction.
    /// * `throttle` is whatever the actual service time exceeds the
    ///   nominal latency by — the DVFS stretch.
    pub fn attribute(arrival: f64, dispatched: f64, completed: f64, cost: &BatchCost) -> Self {
        let queue = (dispatched - arrival).max(0.0);
        let service = (completed - dispatched).max(0.0);
        let nominal = cost.latency.min(service);
        let throttle = service - nominal;
        let busy = cost.dist_busy + cost.compute_busy + cost.collect_busy;
        let (dist, compute, collect) = if busy > 0.0 {
            let dist = nominal * (cost.dist_busy / busy);
            let compute = nominal * (cost.compute_busy / busy);
            // Exact remainder: never lets rounding push the three-way
            // split past the nominal latency.
            (dist, compute, (nominal - dist - compute).max(0.0))
        } else {
            (0.0, 0.0, nominal)
        };
        PhaseBreakdown { queue, dist, compute, collect, throttle }
    }

    /// Sum of all phases — the reconstructed end-to-end latency.
    pub fn total(&self) -> f64 {
        self.queue + self.dist + self.compute + self.collect + self.throttle
    }
}

/// Running sums of [`PhaseBreakdown`]s — one per run, per class, or per
/// package. `Copy` so it rides stats structs without ceremony.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseTotals {
    pub queue: f64,
    pub dist: f64,
    pub compute: f64,
    pub collect: f64,
    pub throttle: f64,
    /// Requests folded in.
    pub requests: u64,
}

impl PhaseTotals {
    /// Fold one completed request's breakdown into the totals.
    pub fn record(&mut self, b: &PhaseBreakdown) {
        self.queue += b.queue;
        self.dist += b.dist;
        self.compute += b.compute;
        self.collect += b.collect;
        self.throttle += b.throttle;
        self.requests += 1;
    }

    /// Merge another accumulator (deterministic: caller fixes the order).
    pub fn merge(&mut self, o: &PhaseTotals) {
        self.queue += o.queue;
        self.dist += o.dist;
        self.compute += o.compute;
        self.collect += o.collect;
        self.throttle += o.throttle;
        self.requests += o.requests;
    }

    /// Total attributed cycles.
    pub fn total(&self) -> f64 {
        self.queue + self.dist + self.compute + self.collect + self.throttle
    }

    /// Phase fractions in [`PHASES`] order; `NaN`s (emitted as JSON
    /// `null`) when nothing has been recorded.
    pub fn fractions(&self) -> [f64; 5] {
        let t = self.total();
        [self.queue / t, self.dist / t, self.compute / t, self.collect / t, self.throttle / t]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(latency: f64, d: f64, c: f64, k: f64) -> BatchCost {
        BatchCost {
            latency,
            dist_busy: d,
            compute_busy: c,
            collect_busy: k,
            macs: 0.0,
            sram_bytes: 0.0,
            dist_energy_pj: 0.0,
            collect_byte_hops: 0.0,
        }
    }

    #[test]
    fn phases_are_nonnegative_and_sum_to_latency() {
        let c = cost(100.0, 30.0, 60.0, 10.0);
        let b = PhaseBreakdown::attribute(5.0, 25.0, 125.0, &c);
        assert!(b.queue >= 0.0 && b.dist >= 0.0 && b.compute >= 0.0);
        assert!(b.collect >= 0.0 && b.throttle >= 0.0);
        crate::assert_close!(b.total(), 120.0);
        crate::assert_close!(b.queue, 20.0);
        // Pro-rata split of the nominal 100-cycle latency.
        crate::assert_close!(b.dist, 30.0);
        crate::assert_close!(b.compute, 60.0);
        crate::assert_close!(b.collect, 10.0);
        assert_eq!(b.throttle, 0.0, "no stretch at nominal service time");
    }

    #[test]
    fn dvfs_stretch_lands_in_throttle() {
        let c = cost(100.0, 50.0, 50.0, 0.0);
        // Service took 150 cycles against a 100-cycle nominal latency.
        let b = PhaseBreakdown::attribute(0.0, 0.0, 150.0, &c);
        crate::assert_close!(b.throttle, 50.0);
        crate::assert_close!(b.total(), 150.0);
    }

    #[test]
    fn zero_busy_planes_fall_back_to_collect() {
        let c = cost(40.0, 0.0, 0.0, 0.0);
        let b = PhaseBreakdown::attribute(0.0, 10.0, 50.0, &c);
        crate::assert_close!(b.collect, 40.0);
        crate::assert_close!(b.queue, 10.0);
    }

    #[test]
    fn totals_merge_and_fraction() {
        let c = cost(100.0, 25.0, 50.0, 25.0);
        let mut a = PhaseTotals::default();
        let mut b = PhaseTotals::default();
        a.record(&PhaseBreakdown::attribute(0.0, 10.0, 110.0, &c));
        b.record(&PhaseBreakdown::attribute(0.0, 30.0, 130.0, &c));
        a.merge(&b);
        assert_eq!(a.requests, 2);
        crate::assert_close!(a.total(), 240.0);
        let f = a.fractions();
        crate::assert_close!(f.iter().sum::<f64>(), 1.0);
        crate::assert_close!(f[0], 40.0 / 240.0);
    }

    #[test]
    fn empty_totals_yield_nan_fractions() {
        let f = PhaseTotals::default().fractions();
        assert!(f.iter().all(|v| v.is_nan()));
    }
}
