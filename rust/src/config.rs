//! System configuration (paper Table 4) and NoP design points.

use crate::nop::NopKind;

/// Bytes per element; the paper's accelerators operate on 8-bit data
/// (NVDLA-style int8 inference), so 1 byte/element. Kept symbolic so the
/// model can be re-run at fp16/fp32.
pub const BYTES_PER_ELEM: u64 = 1;

/// Clock frequency used in Table 4 (cycles <-> seconds conversions).
pub const CLOCK_HZ: f64 = 500e6;

/// Conservative/aggressive axis for both baselines and WIENNA (Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Aggressiveness {
    Conservative,
    Aggressive,
}

impl Aggressiveness {
    pub fn label(&self) -> &'static str {
        match self {
            Aggressiveness::Conservative => "C",
            Aggressiveness::Aggressive => "A",
        }
    }
}

/// One evaluated system design point: which NoP distributes data and how
/// aggressively it is provisioned.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DesignPoint {
    pub nop: NopKind,
    pub aggr: Aggressiveness,
}

impl DesignPoint {
    pub const INTERPOSER_C: DesignPoint = DesignPoint { nop: NopKind::Interposer, aggr: Aggressiveness::Conservative };
    pub const INTERPOSER_A: DesignPoint = DesignPoint { nop: NopKind::Interposer, aggr: Aggressiveness::Aggressive };
    pub const WIENNA_C: DesignPoint = DesignPoint { nop: NopKind::Wireless, aggr: Aggressiveness::Conservative };
    pub const WIENNA_A: DesignPoint = DesignPoint { nop: NopKind::Wireless, aggr: Aggressiveness::Aggressive };

    /// The four design points of Fig 7, in presentation order.
    pub const ALL: [DesignPoint; 4] =
        [Self::INTERPOSER_C, Self::INTERPOSER_A, Self::WIENNA_C, Self::WIENNA_A];

    pub fn label(&self) -> String {
        format!("{}-{}", self.nop.label(), self.aggr.label())
    }

    /// Distribution bandwidth in bytes/cycle at the global-SRAM side
    /// (Table 4: interposer 8-16 B/cyc/link, WIENNA 16-32 B/cyc).
    pub fn distribution_bw(&self) -> f64 {
        match (self.nop, self.aggr) {
            (NopKind::Interposer, Aggressiveness::Conservative) => 8.0,
            (NopKind::Interposer, Aggressiveness::Aggressive) => 16.0,
            (NopKind::Wireless, Aggressiveness::Conservative) => 16.0,
            (NopKind::Wireless, Aggressiveness::Aggressive) => 32.0,
        }
    }
}

/// Full system configuration (Table 4 defaults).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Number of accelerator chiplets in the package.
    pub num_chiplets: u64,
    /// PEs per chiplet (64 in the default 256-chiplet instance).
    pub pes_per_chiplet: u64,
    /// Global SRAM capacity in bytes (13 MiB).
    pub global_sram_bytes: u64,
    /// Wired collection-NoP link bandwidth in bytes/cycle/link.
    pub collection_bw_per_link: f64,
    /// Bytes per tensor element.
    pub bytes_per_elem: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            num_chiplets: 256,
            pes_per_chiplet: 64,
            global_sram_bytes: 13 * 1024 * 1024,
            collection_bw_per_link: 8.0,
            bytes_per_elem: BYTES_PER_ELEM,
        }
    }
}

impl SystemConfig {
    /// Fixed-PE-budget variant used by the Fig-8 cluster-size sweep:
    /// `num_chiplets * pes_per_chiplet == 16384` always.
    pub fn with_chiplets(num_chiplets: u64) -> Self {
        let total_pes = 16384;
        assert!(total_pes % num_chiplets == 0, "chiplet count must divide 16384");
        SystemConfig { num_chiplets, pes_per_chiplet: total_pes / num_chiplets, ..Default::default() }
    }

    /// Total MAC units in the package.
    pub fn total_pes(&self) -> u64 {
        self.num_chiplets * self.pes_per_chiplet
    }

    /// Mesh side length (chiplets are arranged in a √Nc x √Nc grid).
    pub fn mesh_side(&self) -> u64 {
        (self.num_chiplets as f64).sqrt().round() as u64
    }

    /// Average hop count of the mesh NoP, `√Nc / 2` (Table 4).
    pub fn avg_mesh_hops(&self) -> f64 {
        (self.num_chiplets as f64).sqrt() / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_defaults() {
        let c = SystemConfig::default();
        assert_eq!(c.total_pes(), 16384);
        assert_eq!(c.mesh_side(), 16);
        assert!((c.avg_mesh_hops() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn fig8_sweep_preserves_total_pes() {
        for nc in [32, 64, 128, 256, 512, 1024] {
            let c = SystemConfig::with_chiplets(nc);
            assert_eq!(c.total_pes(), 16384);
        }
    }

    #[test]
    fn design_point_bandwidths_match_table4() {
        assert_eq!(DesignPoint::INTERPOSER_C.distribution_bw(), 8.0);
        assert_eq!(DesignPoint::INTERPOSER_A.distribution_bw(), 16.0);
        assert_eq!(DesignPoint::WIENNA_C.distribution_bw(), 16.0);
        assert_eq!(DesignPoint::WIENNA_A.distribution_bw(), 32.0);
        // WIENNA-C and Interposer-A share raw bandwidth — the Fig 7
        // comparison hinges on this.
        assert_eq!(DesignPoint::WIENNA_C.distribution_bw(), DesignPoint::INTERPOSER_A.distribution_bw());
    }
}
