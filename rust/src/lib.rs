//! # WIENNA — Wireless-Enabled 2.5D DNN Accelerator, reproduced
//!
//! Reproduction of *"Dataflow-Architecture Co-Design for 2.5D DNN
//! Accelerators using Wireless Network-on-Package"* (Guirado, Kwon,
//! Abadal, Alarcón, Krishna; 2020).
//!
//! The crate provides:
//!
//! * [`workload`] — DNN layer descriptors, Table-1 layer typing, and the
//!   ResNet-50 / UNet evaluation networks;
//! * [`dataflow`] — the three inter-chiplet partitioning strategies
//!   (KP-CP, NP-CP, YP-XP; Fig 2) and the NVDLA-like / Shidiannao-like
//!   intra-chiplet dataflow mappings;
//! * [`nop`] — interconnect technology models (Table 2), the wireless
//!   transceiver scaling fit (Fig 1), analytical mesh-interposer and
//!   wireless NoP models, and a cycle-level mesh simulator;
//! * [`cost`] — the MAESTRO-like analytical cost model driving every
//!   figure of the evaluation. Its hot path is allocation-free and
//!   memoized: repeated layer shapes resolve through a crate-level
//!   interned memo table (`cost::memo`), and independent (layer,
//!   strategy) and (design point, model) evaluations fan out over a
//!   zero-dependency scoped worker pool (`cost::par`);
//! * [`energy`] — the Table-3 area/power breakdown and Fig-9 distribution
//!   energy comparison;
//! * [`coordinator`] — the WIENNA system layer: adaptive per-layer
//!   strategy selection, distribution/collection scheduling, and dispatch
//!   of real tile compute onto the PJRT runtime;
//! * [`serve`] — a request-serving simulator over fleets of WIENNA
//!   packages: open- and closed-loop request sources over a CNN /
//!   transformer model mix (including recorded per-client trace replay),
//!   a dynamic batcher driven by a memoized cost cache, pluggable routing
//!   policies (round-robin, least-loaded, SLO-aware earliest-deadline),
//!   and tail-latency / goodput / SLO statistics;
//! * [`cluster`] — the datacenter tier above `serve`: shards a large
//!   package fleet across worker threads with a deterministic event merge
//!   (bit-identical stats at any thread count), multi-tenant traffic
//!   classes (interactive / batch / best-effort) with priority scheduling
//!   and optional preemption, per-package admission control (queue caps,
//!   deadline-aware load shedding), and per-class SLO accounting
//!   (`wienna cluster`);
//! * [`search`] — the fleet auto-sizer: enumerate package design points
//!   (chiplet count × PEs × buffer × NoP), prune dominated candidates,
//!   bisect fleet widths on short serve replays, and return the cheapest
//!   fleet meeting a target SLO at a target load (`wienna search`) — or,
//!   with `--pareto`, the full cost × energy/request × p99 non-dominated
//!   front;
//! * [`fault`] — deterministic chaos engineering over the cluster tier:
//!   a seeded [`fault::FaultPlan`] (chiplet-package death, degraded
//!   service, shard stalls, contention spikes, optional repair windows)
//!   applied at exact cycles inside the shard event loop, a shared-medium
//!   MAC contention model stretching the `dist` phase via closed-form
//!   token-queueing delay (`nop::mac::token_wait_cycles`), and the
//!   reaction machinery — capped-backoff retries, failover re-routing of
//!   dead hardware's queues, best-effort-first graceful degradation —
//!   all preserving bit-identical stats at any thread count
//!   (`wienna cluster --faults --contention`);
//! * [`power`] — runtime energy telemetry and power capping: a per-batch
//!   energy meter driven by the cost model's traffic phases (Table-3
//!   calibrated, with idle-chiplet power gating), a power-cap governor
//!   enforcing a fleet watt budget through a deterministic DVFS ladder
//!   (`--power-cap-w`), and the Pareto filtering behind the search's
//!   multi-objective mode;
//! * [`telemetry`] — deterministic observability over `serve` and
//!   `cluster`: always-on cycle attribution (queue / NoP-distribute /
//!   compute / collect / cap-throttle fractions per run, class, and
//!   package), an opt-in request-span recorder with log-bucketed
//!   histograms and per-epoch gauges sampled at the sync barrier, and
//!   Chrome trace-event / metrics-JSON export
//!   (`--trace-out` / `--metrics-out`) — bit-identical at any worker
//!   thread count;
//! * [`runtime`] — loading and executing the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) via the XLA PJRT CPU client
//!   (behind the `pjrt` cargo feature, together with
//!   `coordinator::exec`);
//! * [`report`] — ASCII/CSV renderers used by the benchmark harnesses;
//! * [`anyhow`] — an offline, dependency-free stand-in for the `anyhow`
//!   error crate.
//!
//! ## Feature flags
//!
//! * `pjrt` (off by default) — enables the real-numerics execution path
//!   ([`runtime`], `coordinator::exec`, the `e2e` CLI command and the
//!   `e2e_inference` example). Requires the `xla` PJRT bindings and the
//!   compiled HLO artifacts; everything else — the analytical cost model,
//!   the coordinator, and the serving simulator — builds and tests
//!   without it.
//!
//! ## Quickstart
//!
//! ```no_run
//! use wienna::config::{DesignPoint, SystemConfig};
//! use wienna::cost::{evaluate_model, CostEngine};
//! use wienna::workload::resnet50::resnet50;
//!
//! let sys = SystemConfig::default(); // 256 chiplets x 64 PEs (Table 4)
//! let engine = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
//! let cost = evaluate_model(&engine, &resnet50(16), None); // adaptive
//! println!("{:.0} MACs/cycle", cost.macs_per_cycle);
//! ```

pub mod anyhow;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod dataflow;
pub mod energy;
pub mod fault;
pub mod nop;
pub mod power;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod search;
pub mod serve;
pub mod telemetry;
pub mod testutil;
pub mod workload;
/// Compile-only stub of the `xla` PJRT bindings: keeps the `pjrt`-gated
/// code type-checkable in the offline build (CI runs
/// `cargo check --features pjrt`) while the real bindings are absent.
/// Enable `xla-backend` (and add the real `xla` dependency) to link the
/// actual runtime instead.
#[cfg(all(feature = "pjrt", not(feature = "xla-backend")))]
pub mod xla;
