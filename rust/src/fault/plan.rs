//! Seeded fault plans and their per-shard projection.
//!
//! A [`FaultPlan`] is a declarative list of fault windows over simulated
//! time. Every window is half-open `[at, until)` in cycles: the fault is
//! active at its start cycle and repaired at its end (an omitted end
//! means permanent). Plans are pure data — applying them is the job of
//! `cluster::shard` (dispatch skips, retries) and `cluster::sync`
//! (failover, drain accounting) — so injection cannot introduce any
//! cross-shard coupling beyond what the epoch barrier already carries.
//!
//! The CLI grammar (`wienna cluster --faults SPEC`) is a `;`-separated
//! clause list with all times in milliseconds:
//!
//! ```text
//! kill:<pkg>@<start>[..<end>]          package death (global index)
//! degrade:<pkg>:<factor>@<start>[..<end>]   package runs at <factor> speed
//! stall:<shard>@<start>[..<end>]       shard dispatches nothing
//! spike:<extra>@<start>[..<end>]       extra shared-medium load
//! ```
//!
//! e.g. `--faults "kill:1@4;spike:0.5@2..8"` kills package 1 permanently
//! at 4 ms and adds 0.5 of background MAC load between 2 ms and 8 ms.

use crate::anyhow::{bail, Context, Result};
use crate::serve::ms_to_cycles;

/// What a fault window does while active.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The package (global, pre-striping index) serves nothing: its
    /// in-flight batch aborts, queued work re-routes or fails over.
    PackageDeath { package: usize },
    /// The package serves at `factor` (in `(0, 1]`) of nominal speed —
    /// chiplet degradation stretching every batch it runs.
    Degrade { package: usize, factor: f64 },
    /// The shard dispatches nothing (arrivals still queue; admission
    /// still applies) — a coordinator hang, not a hardware loss.
    ShardStall { shard: usize },
    /// Extra shared-medium background load (added to
    /// `ContentionConfig::background_load`) while the window is active.
    ContentionSpike { extra_load: f64 },
}

/// One fault window: `kind` is active over `[at_cycle, until_cycle)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at_cycle: f64,
    /// `f64::INFINITY` = never repaired.
    pub until_cycle: f64,
    pub kind: FaultKind,
}

/// A deterministic chaos scenario: fault windows over simulated time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the CLI `--faults` grammar (times in milliseconds; see the
    /// module docs for the clause list).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            events.push(parse_clause(clause).with_context(|| format!("fault clause '{clause}'"))?);
        }
        Ok(FaultPlan { events })
    }

    /// Merged union of every package-death window — the cluster-wide
    /// outage intervals "goodput during failover" is measured over.
    pub fn outage_intervals(&self) -> Vec<(f64, f64)> {
        let mut spans: Vec<(f64, f64)> = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::PackageDeath { .. }))
            .map(|e| (e.at_cycle, e.until_cycle))
            .collect();
        spans.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut merged: Vec<(f64, f64)> = Vec::new();
        for (s, e) in spans {
            match merged.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => merged.push((s, e)),
            }
        }
        merged
    }

    /// Project the plan onto one shard of a `shards`-way cluster with
    /// `local_packages` packages on that shard. Global package `g` lives
    /// on shard `g % shards` at local index `g / shards` (the
    /// `Cluster::new` round-robin placement). Faults naming packages or
    /// shards outside the cluster are ignored — a plan written for a
    /// bigger fleet still parses and applies where it can.
    pub fn for_shard(&self, shard: usize, shards: usize, local_packages: usize) -> ShardFaults {
        let mut f = ShardFaults::empty(local_packages);
        for ev in &self.events {
            let win = (ev.at_cycle, ev.until_cycle);
            match ev.kind {
                FaultKind::PackageDeath { package } => {
                    if package % shards == shard && package / shards < local_packages {
                        f.dead[package / shards].push(win);
                    }
                }
                FaultKind::Degrade { package, factor } => {
                    if package % shards == shard && package / shards < local_packages {
                        f.degrade[package / shards].push((win.0, win.1, factor));
                    }
                }
                FaultKind::ShardStall { shard: s } => {
                    if s == shard {
                        f.stalls.push(win);
                    }
                }
                FaultKind::ContentionSpike { extra_load } => {
                    f.spikes.push((win.0, win.1, extra_load));
                }
            }
        }
        f.outages = self.outage_intervals();
        f.collect_edges();
        f
    }
}

fn parse_clause(clause: &str) -> Result<FaultEvent> {
    let (head, window) =
        clause.split_once('@').context("missing '@<start_ms>[..<end_ms>]' window")?;
    let (at_cycle, until_cycle) = parse_window(window)?;
    let mut parts = head.split(':');
    let kind = match parts.next().unwrap_or("") {
        "kill" => FaultKind::PackageDeath { package: parse_index(parts.next(), "package")? },
        "degrade" => {
            let package = parse_index(parts.next(), "package")?;
            let factor: f64 =
                parts.next().context("degrade needs ':<factor>'")?.parse().context("factor")?;
            if !(factor > 0.0 && factor <= 1.0) {
                bail!("degrade factor {factor} outside (0, 1]");
            }
            FaultKind::Degrade { package, factor }
        }
        "stall" => FaultKind::ShardStall { shard: parse_index(parts.next(), "shard")? },
        "spike" => {
            let extra_load: f64 =
                parts.next().context("spike needs ':<extra_load>'")?.parse().context("extra load")?;
            if !(extra_load >= 0.0 && extra_load.is_finite()) {
                bail!("spike load {extra_load} must be finite and >= 0");
            }
            FaultKind::ContentionSpike { extra_load }
        }
        other => bail!("unknown fault kind '{other}' (kill|degrade|stall|spike)"),
    };
    if parts.next().is_some() {
        bail!("trailing ':' fields");
    }
    Ok(FaultEvent { at_cycle, until_cycle, kind })
}

fn parse_index(part: Option<&str>, what: &str) -> Result<usize> {
    part.with_context(|| format!("missing {what} index"))?
        .parse()
        .with_context(|| format!("{what} index"))
}

fn parse_window(window: &str) -> Result<(f64, f64)> {
    let (start_ms, end_ms) = match window.split_once("..") {
        Some((s, e)) => {
            (s.parse::<f64>().context("start ms")?, e.parse::<f64>().context("end ms")?)
        }
        None => (window.parse::<f64>().context("start ms")?, f64::INFINITY),
    };
    if !(start_ms >= 0.0 && start_ms.is_finite()) {
        bail!("start {start_ms} ms must be finite and >= 0");
    }
    if end_ms <= start_ms {
        bail!("window end {end_ms} ms must be after start {start_ms} ms");
    }
    Ok((ms_to_cycles(start_ms), if end_ms.is_finite() { ms_to_cycles(end_ms) } else { f64::INFINITY }))
}

fn covering<'a, I: Iterator<Item = &'a (f64, f64)>>(spans: I, t: f64) -> Option<&'a (f64, f64)> {
    spans.into_iter().find(|(s, e)| *s <= t && t < *e)
}

/// One shard's view of a [`FaultPlan`]: local-package fault windows plus
/// the global spike/outage windows, pre-projected so the per-shard hot
/// path answers every query with a scan over a handful of intervals and
/// no knowledge of the rest of the cluster.
#[derive(Debug, Clone, Default)]
pub struct ShardFaults {
    /// Per local package: `[start, end)` death windows.
    dead: Vec<Vec<(f64, f64)>>,
    /// Per local package: `(start, end, factor)` degradation windows.
    degrade: Vec<Vec<(f64, f64, f64)>>,
    /// Shard-wide dispatch stalls.
    stalls: Vec<(f64, f64)>,
    /// Cluster-wide `(start, end, extra_load)` contention spikes.
    spikes: Vec<(f64, f64, f64)>,
    /// Merged cluster-wide package-death windows (failover-goodput
    /// accounting counts completions landing inside these).
    outages: Vec<(f64, f64)>,
    /// Sorted, deduplicated finite window edges relevant to this shard —
    /// the cycles at which dispatch eligibility can change.
    edges: Vec<f64>,
}

impl ShardFaults {
    pub fn empty(local_packages: usize) -> Self {
        ShardFaults {
            dead: vec![Vec::new(); local_packages],
            degrade: vec![Vec::new(); local_packages],
            ..Default::default()
        }
    }

    fn collect_edges(&mut self) {
        let mut edges = Vec::new();
        let mut push = |s: f64, e: f64| {
            edges.push(s);
            if e.is_finite() {
                edges.push(e);
            }
        };
        for spans in &self.dead {
            spans.iter().for_each(|&(s, e)| push(s, e));
        }
        for spans in &self.degrade {
            spans.iter().for_each(|&(s, e, _)| push(s, e));
        }
        self.stalls.iter().for_each(|&(s, e)| push(s, e));
        self.spikes.iter().for_each(|&(s, e, _)| push(s, e));
        edges.sort_by(|a, b| a.total_cmp(b));
        edges.dedup();
        self.edges = edges;
    }

    /// No fault ever affects this shard — spikes and cluster-wide outage
    /// windows included (the latter drive failover-goodput accounting on
    /// shards with no local fault of their own).
    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
            && self.dead.iter().all(Vec::is_empty)
            && self.degrade.iter().all(Vec::is_empty)
            && self.stalls.is_empty()
            && self.spikes.is_empty()
            && self.outages.is_empty()
    }

    /// Next cycle strictly after `t` at which a fault window opens or
    /// closes on this shard.
    pub fn next_edge_after(&self, t: f64) -> Option<f64> {
        let i = self.edges.partition_point(|&e| e <= t);
        self.edges.get(i).copied()
    }

    /// Is local package `p` dead at cycle `t`?
    pub fn package_dead(&self, p: usize, t: f64) -> bool {
        covering(self.dead[p].iter(), t).is_some()
    }

    /// End of the death window covering `(p, t)`, if it is dead
    /// (`f64::INFINITY` = never repaired).
    pub fn dead_until(&self, p: usize, t: f64) -> Option<f64> {
        covering(self.dead[p].iter(), t).map(|&(_, e)| e)
    }

    /// Speed factor of local package `p` at `t`: 1.0 healthy, the
    /// minimum active degradation factor otherwise (overlapping windows
    /// do not compound — the slowest one governs).
    pub fn degrade_factor(&self, p: usize, t: f64) -> f64 {
        self.degrade[p]
            .iter()
            .filter(|(s, e, _)| *s <= t && t < *e)
            .map(|&(_, _, f)| f)
            .fold(1.0, f64::min)
    }

    /// Is the whole shard's dispatcher stalled at `t`?
    pub fn stalled(&self, t: f64) -> bool {
        covering(self.stalls.iter(), t).is_some()
    }

    /// Extra shared-medium load from active contention spikes at `t`
    /// (concurrent spikes sum).
    pub fn spike_extra(&self, t: f64) -> f64 {
        self.spikes.iter().filter(|(s, e, _)| *s <= t && t < *e).map(|&(_, _, x)| x).sum()
    }

    /// Is any package cluster-wide dead at `t` (the failover-goodput
    /// measurement window)?
    pub fn in_outage(&self, t: f64) -> bool {
        covering(self.outages.iter(), t).is_some()
    }

    /// Is every local package of this shard dead at `t`? (`false` for a
    /// shard with no packages — nothing to fail.)
    pub fn fully_dead(&self, t: f64) -> bool {
        !self.dead.is_empty() && (0..self.dead.len()).all(|p| self.package_dead(p, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_every_clause_kind() {
        let plan = FaultPlan::parse("kill:1@4; degrade:0:0.5@1..3 ;stall:2@0..9;spike:0.5@2..8")
            .expect("valid spec");
        assert_eq!(plan.events.len(), 4);
        assert_eq!(plan.events[0].kind, FaultKind::PackageDeath { package: 1 });
        assert_eq!(plan.events[0].at_cycle, ms_to_cycles(4.0));
        assert_eq!(plan.events[0].until_cycle, f64::INFINITY, "no end = permanent");
        assert_eq!(plan.events[1].kind, FaultKind::Degrade { package: 0, factor: 0.5 });
        assert_eq!(plan.events[1].until_cycle, ms_to_cycles(3.0));
        assert_eq!(plan.events[2].kind, FaultKind::ShardStall { shard: 2 });
        assert_eq!(plan.events[3].kind, FaultKind::ContentionSpike { extra_load: 0.5 });
    }

    #[test]
    fn parse_rejects_malformed_clauses() {
        for bad in [
            "kill:1",            // no window
            "kill@4",            // no index
            "kill:x@4",          // bad index
            "degrade:0@1",       // no factor
            "degrade:0:1.5@1",   // factor > 1
            "degrade:0:0@1",     // factor 0
            "spike:-0.5@1",      // negative load
            "kill:1@5..3",       // end before start
            "kill:1@-1",         // negative start
            "kill:1:2:3@4",      // trailing fields
            "explode:1@4",       // unknown kind
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should not parse");
        }
        assert!(FaultPlan::parse("").expect("empty spec").is_empty());
    }

    #[test]
    fn for_shard_maps_global_packages_by_round_robin_stripe() {
        // 8 packages over 4 shards: global 1 and 5 both land on shard 1
        // (locals 0 and 1); global 2 lands on shard 2.
        let plan = FaultPlan::parse("kill:1@1;kill:5@2;degrade:2:0.5@0..9").unwrap();
        let s1 = plan.for_shard(1, 4, 2);
        assert!(s1.package_dead(0, ms_to_cycles(1.0)));
        assert!(!s1.package_dead(0, ms_to_cycles(0.5)), "window has not opened yet");
        assert!(s1.package_dead(1, ms_to_cycles(2.0)));
        assert!(s1.fully_dead(ms_to_cycles(2.0)));
        assert!(!s1.fully_dead(ms_to_cycles(1.5)), "only one of two packages dead");
        let s2 = plan.for_shard(2, 4, 2);
        assert!(!s2.package_dead(0, ms_to_cycles(3.0)));
        assert_eq!(s2.degrade_factor(0, ms_to_cycles(3.0)), 0.5);
        assert_eq!(s2.degrade_factor(0, ms_to_cycles(9.5)), 1.0, "repaired at 9 ms");
        // Shard 0 sees no local faults but still knows the outages.
        let s0 = plan.for_shard(0, 4, 2);
        assert!(s0.in_outage(ms_to_cycles(3.0)));
        assert!(!s0.is_empty(), "outage edge-free but spike/owner queries still live");
    }

    #[test]
    fn edges_and_windows_are_half_open() {
        let plan = FaultPlan::parse("stall:0@1..2;spike:0.25@1..4").unwrap();
        let f = plan.for_shard(0, 1, 1);
        assert!(f.stalled(ms_to_cycles(1.0)), "active at its start cycle");
        assert!(!f.stalled(ms_to_cycles(2.0)), "repaired at its end cycle");
        assert_eq!(f.spike_extra(ms_to_cycles(3.0)), 0.25);
        assert_eq!(f.spike_extra(ms_to_cycles(4.0)), 0.0);
        // Edges: 1, 2, 4 ms; strictly-after semantics.
        assert_eq!(f.next_edge_after(0.0), Some(ms_to_cycles(1.0)));
        assert_eq!(f.next_edge_after(ms_to_cycles(1.0)), Some(ms_to_cycles(2.0)));
        assert_eq!(f.next_edge_after(ms_to_cycles(4.0)), None);
    }

    #[test]
    fn outage_intervals_merge_overlaps() {
        let plan = FaultPlan::parse("kill:0@1..4;kill:1@2..6;kill:2@8..9").unwrap();
        assert_eq!(
            plan.outage_intervals(),
            vec![
                (ms_to_cycles(1.0), ms_to_cycles(6.0)),
                (ms_to_cycles(8.0), ms_to_cycles(9.0))
            ]
        );
    }

    #[test]
    fn dead_until_reports_repair_and_permanence() {
        let plan = FaultPlan::parse("kill:0@1..4;kill:1@2").unwrap();
        let f = plan.for_shard(0, 2, 1);
        assert_eq!(f.dead_until(0, ms_to_cycles(2.0)), Some(ms_to_cycles(4.0)));
        assert_eq!(f.dead_until(0, ms_to_cycles(5.0)), None, "already repaired");
        let g = plan.for_shard(1, 2, 1);
        assert_eq!(g.dead_until(0, ms_to_cycles(3.0)), Some(f64::INFINITY));
    }

    #[test]
    fn overlapping_degradations_take_the_slowest_factor() {
        let plan = FaultPlan::parse("degrade:0:0.8@0..10;degrade:0:0.25@2..4").unwrap();
        let f = plan.for_shard(0, 1, 1);
        assert_eq!(f.degrade_factor(0, ms_to_cycles(1.0)), 0.8);
        assert_eq!(f.degrade_factor(0, ms_to_cycles(3.0)), 0.25);
        assert_eq!(f.degrade_factor(0, ms_to_cycles(5.0)), 0.8);
    }
}
