//! Deterministic contention and failure injection for the serving stack.
//!
//! The paper's shared wireless medium and the cluster tier's scale both
//! invite failure modes the simulator never exercised: chiplets degrade,
//! packages die, shards stall, and concurrent multicasts on co-packaged
//! chiplets contend for the token-passing MAC. This module is the
//! chaos-engineering layer that injects all of them **deterministically**
//! — every fault fires at a seeded cycle from a declarative plan, so the
//! 1/2/4-thread stats-JSON byte-identity contract survives intact:
//!
//! * [`plan`] — the [`FaultPlan`]: a list of `[start, end)` fault windows
//!   (package death, chiplet degradation, shard stall, contention spike)
//!   parsed from the CLI `--faults` grammar, plus the per-shard
//!   [`ShardFaults`] projection `ShardSim` queries on its hot path;
//! * [`contention`] — [`ContentionConfig`]: the shared-medium background
//!   load that stretches every dispatch's `dist` phase through the
//!   closed-form token-wait model in [`crate::nop::mac`], and the
//!   sustained-load threshold above which best-effort work is shed
//!   (graceful degradation);
//! * [`retry`] — [`RetryPolicy`]: capped exponential backoff for
//!   requests whose dispatch died under them before they fail for good.
//!
//! Reaction paths live where the machinery already is: retries and
//! re-routing inside `cluster::shard`, dead-shard failover riding the
//! `cluster::sync::steal_pass` barrier, and closed-loop clients observing
//! failures through the same completion-feedback hook as sheds.

pub mod contention;
pub mod plan;
pub mod retry;

pub use contention::ContentionConfig;
pub use plan::{FaultEvent, FaultKind, FaultPlan, ShardFaults};
pub use retry::RetryPolicy;
