//! Shared-medium contention configuration.
//!
//! The wireless NoP is a broadcast medium: only one transmitter per
//! package speaks at a time, arbitrated by the token-passing MAC
//! (`nop::mac`). Co-packaged chiplet multicasts therefore *serialize*,
//! and under background load every dispatch's distribution phase waits
//! for the token before it can stream. [`ContentionConfig`] sets that
//! background load; the closed-form token-wait delay itself lives in
//! [`crate::nop::mac::token_wait_cycles`] and is applied by
//! `cluster::shard` when it prices a dispatch, so the meter and the
//! five-phase attribution pick the stretch up automatically (it lands in
//! `dist_frac`).

/// Shared-medium contention knobs. The default is fully disabled:
/// `enabled == false` skips the stretch arithmetic entirely, keeping the
/// no-contention cluster path bit-identical to the pre-fault engine.
#[derive(Debug, Clone, Copy)]
pub struct ContentionConfig {
    /// Model MAC contention at all.
    pub enabled: bool,
    /// Steady background occupancy of the shared medium in `[0, 1)` —
    /// the fraction of token time other (un-simulated) traffic holds.
    /// `FaultKind::ContentionSpike` windows add on top of this.
    pub background_load: f64,
    /// Sustained effective load at or above which arriving best-effort
    /// requests are shed (`ShedReason::Overload`) — graceful degradation
    /// sheds the lowest class first instead of letting contention stretch
    /// every class's tail.
    pub shed_best_effort_above: f64,
}

impl Default for ContentionConfig {
    fn default() -> Self {
        ContentionConfig { enabled: false, background_load: 0.0, shed_best_effort_above: 0.9 }
    }
}

impl ContentionConfig {
    /// Enabled with the given steady background load.
    pub fn with_background(background_load: f64) -> Self {
        assert!(
            (0.0..1.0).contains(&background_load),
            "background load {background_load} outside [0, 1)"
        );
        ContentionConfig { enabled: true, background_load, ..Default::default() }
    }

    /// Effective shared-medium load at dispatch time: the steady
    /// background plus whatever contention-spike windows are active.
    pub fn effective_load(&self, spike_extra: f64) -> f64 {
        self.background_load + spike_extra
    }

    /// Does graceful degradation shed an arriving best-effort request at
    /// this effective load?
    pub fn sheds_best_effort(&self, effective_load: f64) -> bool {
        self.enabled && effective_load >= self.shed_best_effort_above
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_fully_disabled() {
        let c = ContentionConfig::default();
        assert!(!c.enabled);
        assert_eq!(c.background_load, 0.0);
        assert!(!c.sheds_best_effort(2.0), "disabled config never sheds");
    }

    #[test]
    fn spikes_stack_on_background_and_trigger_shedding() {
        let c = ContentionConfig::with_background(0.5);
        assert_eq!(c.effective_load(0.0), 0.5);
        assert!(!c.sheds_best_effort(c.effective_load(0.0)));
        assert!(c.sheds_best_effort(c.effective_load(0.45)), "0.95 >= 0.9 threshold");
    }
}
