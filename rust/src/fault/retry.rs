//! Capped exponential backoff, with seeded decorrelating jitter, for
//! failed dispatches.
//!
//! When a package dies under a request's in-flight batch (or a retry
//! lands on a shard whose packages are all dead), the request is not
//! silently completed or dropped: it waits a backoff and tries again,
//! up to a cap, after which it is **failed** — a terminal disposition
//! the closed-loop clients observe like any completion.
//!
//! Synchronized deterministic backoff is the worst case for retry
//! storms: every request failed by one package kill retries at exactly
//! the same cycle and hammers the survivors in lockstep. The jitter
//! spreads those retries across a window *without* giving up the
//! cluster's bit-identical-at-any-thread-count guarantee — it is a pure
//! hash of `(jitter_seed, request id, attempt)`, independent of
//! simulation state or thread schedule, exactly like
//! `ClassMix::assign`'s class tagging.

/// Retry knobs for requests whose dispatch died under them.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Retries before a request fails for good. 0 = fail immediately.
    pub max_retries: u32,
    /// Backoff before the first retry, in cycles.
    pub base_backoff_cycles: f64,
    /// Ceiling on the exponential backoff, in cycles.
    pub max_backoff_cycles: f64,
    /// Jitter fraction in `[0, 1]`: retry `attempt` of request `id`
    /// waits `backoff * (1 - jitter * u(id, attempt))` with
    /// `u ∈ [0, 1)` — full backoff at 0.0, "anywhere in the second
    /// half of the window" at the 0.5 default, full decorrelation at
    /// 1.0. Always `<=` the un-jittered backoff, so the cap holds.
    pub jitter: f64,
    /// Seed for the per-request jitter hash; fixed by default so runs
    /// stay reproducible, settable to decorrelate whole experiments.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff_cycles: crate::serve::ms_to_cycles(0.1),
            max_backoff_cycles: crate::serve::ms_to_cycles(1.0),
            jitter: 0.5,
            jitter_seed: 0x9E3779B9,
        }
    }
}

impl RetryPolicy {
    /// Un-jittered backoff before retry `attempt` (1-based):
    /// `base * 2^(attempt-1)`, capped. The jittered schedule never
    /// exceeds this — it is both the storm worst case and the test
    /// anchor.
    pub fn backoff_cycles(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(52);
        (self.base_backoff_cycles * (1u64 << exp) as f64).min(self.max_backoff_cycles)
    }

    /// Jittered backoff before retry `attempt` of request `id`: the
    /// capped exponential scaled into
    /// `[(1 - jitter) * backoff, backoff]` by a SplitMix64-style hash
    /// of `(jitter_seed, id, attempt)`. Deterministic — a pure function
    /// of its arguments, so the same request retries at the same cycle
    /// under any shard layout or thread count.
    pub fn backoff_cycles_jittered(&self, id: u64, attempt: u32) -> f64 {
        let base = self.backoff_cycles(attempt);
        if self.jitter <= 0.0 {
            return base;
        }
        // SplitMix64 finalizer over the combined key: one avalanche
        // pass decorrelates consecutive ids and attempts fully.
        let mut z = self
            .jitter_seed
            .wrapping_add(id.wrapping_mul(0x9E3779B97F4A7C15))
            .wrapping_add((attempt as u64).wrapping_mul(0xBF58476D1CE4E5B9));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        let u = (z >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
        base * (1.0 - self.jitter.min(1.0) * u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_then_caps() {
        let p = RetryPolicy {
            max_retries: 5,
            base_backoff_cycles: 10.0,
            max_backoff_cycles: 35.0,
            ..Default::default()
        };
        assert_eq!(p.backoff_cycles(1), 10.0);
        assert_eq!(p.backoff_cycles(2), 20.0);
        assert_eq!(p.backoff_cycles(3), 35.0, "capped below 40");
        assert_eq!(p.backoff_cycles(100), 35.0, "huge attempts stay finite at the cap");
    }

    #[test]
    fn jitter_is_deterministic_and_stays_in_window() {
        let p = RetryPolicy {
            max_retries: 5,
            base_backoff_cycles: 10.0,
            max_backoff_cycles: 1000.0,
            jitter: 0.5,
            jitter_seed: 42,
        };
        for id in 0..200u64 {
            for attempt in 1..=4u32 {
                let a = p.backoff_cycles_jittered(id, attempt);
                let b = p.backoff_cycles_jittered(id, attempt);
                assert_eq!(a, b, "pure function of (seed, id, attempt)");
                let full = p.backoff_cycles(attempt);
                assert!(
                    a > 0.0 && a <= full && a >= full * 0.5 - 1e-9,
                    "id {id} attempt {attempt}: {a} outside [{}, {full}]",
                    full * 0.5
                );
            }
        }
    }

    #[test]
    fn jitter_desynchronizes_the_storm() {
        // The whole point: two requests failed by the same kill must not
        // retry at the same cycle.
        let p = RetryPolicy::default();
        let offsets: Vec<f64> = (0..50).map(|id| p.backoff_cycles_jittered(id, 1)).collect();
        let mut distinct = offsets.clone();
        distinct.sort_by(f64::total_cmp);
        distinct.dedup();
        assert!(distinct.len() >= 45, "only {} distinct backoffs across 50 ids", distinct.len());
    }

    #[test]
    fn zero_jitter_recovers_the_synchronized_schedule() {
        let p = RetryPolicy { jitter: 0.0, ..Default::default() };
        for id in [0u64, 7, 99] {
            for attempt in 1..=3u32 {
                assert_eq!(p.backoff_cycles_jittered(id, attempt), p.backoff_cycles(attempt));
            }
        }
    }

    #[test]
    fn jitter_seed_steers_the_offsets() {
        let a = RetryPolicy { jitter_seed: 1, ..Default::default() };
        let b = RetryPolicy { jitter_seed: 2, ..Default::default() };
        let differs =
            (0..50u64).any(|id| a.backoff_cycles_jittered(id, 1) != b.backoff_cycles_jittered(id, 1));
        assert!(differs, "the seed must steer the jitter");
    }
}
