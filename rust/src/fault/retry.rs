//! Capped exponential backoff for failed dispatches.
//!
//! When a package dies under a request's in-flight batch (or a retry
//! lands on a shard whose packages are all dead), the request is not
//! silently completed or dropped: it waits a deterministic backoff and
//! tries again, up to a cap, after which it is **failed** — a terminal
//! disposition the closed-loop clients observe like any completion.

/// Retry knobs for requests whose dispatch died under them.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Retries before a request fails for good. 0 = fail immediately.
    pub max_retries: u32,
    /// Backoff before the first retry, in cycles.
    pub base_backoff_cycles: f64,
    /// Ceiling on the exponential backoff, in cycles.
    pub max_backoff_cycles: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff_cycles: crate::serve::ms_to_cycles(0.1),
            max_backoff_cycles: crate::serve::ms_to_cycles(1.0),
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (1-based): `base * 2^(attempt-1)`,
    /// capped. Deterministic — no jitter, so the 1/2/4-thread byte
    /// identity of the stats JSON is untouched.
    pub fn backoff_cycles(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(52);
        (self.base_backoff_cycles * (1u64 << exp) as f64).min(self.max_backoff_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_then_caps() {
        let p = RetryPolicy { max_retries: 5, base_backoff_cycles: 10.0, max_backoff_cycles: 35.0 };
        assert_eq!(p.backoff_cycles(1), 10.0);
        assert_eq!(p.backoff_cycles(2), 20.0);
        assert_eq!(p.backoff_cycles(3), 35.0, "capped below 40");
        assert_eq!(p.backoff_cycles(100), 35.0, "huge attempts stay finite at the cap");
    }
}
