//! `wienna report <metrics.json|.jsonl>` — the offline artifact
//! analyzer: everything it renders comes from an emitted telemetry
//! artifact alone, no re-simulation.
//!
//! Accepts either the buffered `wienna-metrics-v1` JSON or a
//! `wienna-metrics-stream-v1` JSONL stream (reconstructed through
//! [`crate::telemetry::stream_to_metrics_v1`] first), and renders:
//!
//! * the percentile table — p50/p95/p99/mean per histogram track,
//!   re-estimated from the exported log buckets via
//!   [`LogHistogram::quantile`] (within one power-of-two bucket of the
//!   exact value, see that method's error bound);
//! * the phase-attribution bottleneck verdict (+ the `dist_alarm`
//!   shared-medium flag);
//! * the SLO burn-rate alarm timeline with exact raise/clear cycles;
//! * the top-N packages by MAC occupancy at the last epoch barrier,
//!   with their cumulative token-wait cycles;
//! * optionally (`--trace FILE`) a Chrome-trace event census.
//!
//! The JSON reader is a minimal recursive-descent parser over the
//! crate's own hand-rolled emitters — offline build, no serde.

use crate::anyhow::{bail, Context, Result};
use crate::report::table::fmt;
use crate::report::Table;
use crate::telemetry::{LogHistogram, QuantileSketch, METRICS_STREAM_SCHEMA, PHASES};

/// A parsed JSON value. Object fields keep emission order (`Vec`, not a
/// map) — the artifacts are schema-pinned, order is meaningful.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `get` + number in one step; `None` for missing, null or non-numeric.
    pub(crate) fn num(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }
}

struct Parser {
    c: Vec<char>,
    i: usize,
}

impl Parser {
    fn ws(&mut self) {
        while self.i < self.c.len() && self.c[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.c.get(self.i).copied()
    }

    fn eat(&mut self, ch: char) -> Result<()> {
        if self.peek() == Some(ch) {
            self.i += 1;
            Ok(())
        } else {
            bail!("JSON parse error at char {}: expected '{ch}'", self.i)
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        for ch in word.chars() {
            self.eat(ch)?;
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.eat('"')?;
        let mut out = String::new();
        loop {
            let ch = self.peek().context("JSON parse error: unterminated string")?;
            self.i += 1;
            match ch {
                '"' => return Ok(out),
                '\\' => {
                    let esc = self.peek().context("JSON parse error: dangling escape")?;
                    self.i += 1;
                    match esc {
                        '"' => out.push('"'),
                        '\\' => out.push('\\'),
                        '/' => out.push('/'),
                        'n' => out.push('\n'),
                        't' => out.push('\t'),
                        'r' => out.push('\r'),
                        'u' => {
                            let hex: String = (0..4)
                                .map(|_| {
                                    let h = self.peek().unwrap_or('!');
                                    self.i += 1;
                                    h
                                })
                                .collect();
                            let code = u32::from_str_radix(&hex, 16)
                                .map_err(|_| crate::anyhow::Error::msg("bad \\u escape"))?;
                            out.push(char::from_u32(code).context("bad \\u codepoint")?);
                        }
                        other => bail!("JSON parse error: unknown escape '\\{other}'"),
                    }
                }
                other => out.push(other),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || "+-.eE".contains(c))
        {
            self.i += 1;
        }
        let text: String = self.c[start..self.i].iter().collect();
        let v: f64 = text
            .parse()
            .map_err(|_| crate::anyhow::Error::msg(format!("bad JSON number '{text}'")))?;
        Ok(Json::Num(v))
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek().context("JSON parse error: unexpected end of input")? {
            '{' => {
                self.eat('{')?;
                let mut fields = Vec::new();
                self.ws();
                if self.peek() == Some('}') {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.ws();
                    let key = self.string()?;
                    self.ws();
                    self.eat(':')?;
                    let v = self.value()?;
                    fields.push((key, v));
                    self.ws();
                    match self.peek() {
                        Some(',') => self.i += 1,
                        Some('}') => {
                            self.i += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => bail!("JSON parse error at char {}: expected ',' or '}}'", self.i),
                    }
                }
            }
            '[' => {
                self.eat('[')?;
                let mut items = Vec::new();
                self.ws();
                if self.peek() == Some(']') {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(',') => self.i += 1,
                        Some(']') => {
                            self.i += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => bail!("JSON parse error at char {}: expected ',' or ']'", self.i),
                    }
                }
            }
            '"' => Ok(Json::Str(self.string()?)),
            't' => self.literal("true", Json::Bool(true)),
            'f' => self.literal("false", Json::Bool(false)),
            'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse_json(text: &str) -> Result<Json> {
    let mut p = Parser { c: text.chars().collect(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.c.len() {
        bail!("JSON parse error: trailing garbage at char {}", p.i);
    }
    Ok(v)
}

/// Finite → engineering format, non-finite (exported as `null`) → "-".
fn cell(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => fmt(x),
        _ => "-".to_string(),
    }
}

/// Rebuild a [`LogHistogram`] from its exported bucket list so the
/// analyzer (and the `--diff` regression gate) can re-run quantile
/// estimation offline.
pub(crate) fn histogram_from(obj: &Json) -> Result<(String, LogHistogram)> {
    let name = obj.get("name").and_then(Json::as_str).context("histogram missing name")?;
    let mut h = LogHistogram::default();
    h.count = obj.num("count").context("histogram missing count")? as u64;
    h.sum = obj.get("sum").and_then(Json::as_f64).unwrap_or(f64::NAN);
    for b in obj.get("buckets").and_then(Json::as_arr).context("histogram missing buckets")? {
        let exp = match b.get("exp") {
            Some(Json::Null) => i32::MIN, // the zero/negative/NaN sentinel
            Some(j) => j.as_f64().context("bucket exp is not a number")? as i32,
            None => bail!("bucket missing exp"),
        };
        let n = b.num("count").context("bucket missing count")? as u64;
        h.buckets.insert(exp, n);
    }
    Ok((name.to_string(), h))
}

/// One entry of the artifact's `sketches` block, rebuilt into a live
/// [`QuantileSketch`]. Bounded-stats runs export these alongside the
/// power-of-two histograms so the analyzer can answer quantiles at the
/// same ε resolution as the run's stats line, instead of degrading to
/// within-one-power-of-two histogram estimates.
pub(crate) struct SketchTrack {
    pub(crate) name: String,
    pub(crate) count: u64,
    /// Recorded-unit → display-unit factor (sketches store cycles; the
    /// artifact carries the run's cycles→ms conversion).
    scale: f64,
    sketch: QuantileSketch,
}

impl SketchTrack {
    /// Percentile in display units (ms for the latency tracks).
    pub(crate) fn quantile(&self, p: f64) -> f64 {
        self.sketch.quantile(p) * self.scale
    }

    pub(crate) fn mean(&self) -> f64 {
        self.sketch.mean() * self.scale
    }

    /// The sketch's relative error bound ε.
    pub(crate) fn eps(&self) -> f64 {
        self.sketch.relative_error()
    }
}

/// Rebuild one sketch from its exported `[key, count]` bucket list
/// (finite keys only; the zero/overflow sentinels travel as separate
/// counts because their `i64::MIN`/`MAX` keys are not exact doubles).
pub(crate) fn sketch_from(obj: &Json) -> Result<SketchTrack> {
    let name = obj.get("name").and_then(Json::as_str).context("sketch missing name")?;
    let sub_bits = obj.num("sub_bits").context("sketch missing sub_bits")? as u32;
    let scale = obj.num("scale").context("sketch missing scale")?;
    let zero = obj.num("zero").unwrap_or(0.0) as u64;
    let inf = obj.num("inf").unwrap_or(0.0) as u64;
    let sum = obj.get("sum").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let max = obj.get("max").and_then(Json::as_f64).unwrap_or(f64::NEG_INFINITY);
    let mut buckets = Vec::new();
    for b in obj.get("buckets").and_then(Json::as_arr).context("sketch missing buckets")? {
        let pair = b.as_arr().context("sketch bucket is not a [key, count] pair")?;
        let k = pair.first().and_then(Json::as_f64).context("sketch bucket missing key")? as i64;
        let c = pair.get(1).and_then(Json::as_f64).context("sketch bucket missing count")? as u64;
        buckets.push((k, c));
    }
    let sketch = QuantileSketch::from_parts(sub_bits, buckets, zero, inf, sum, max);
    Ok(SketchTrack { name: name.to_string(), count: sketch.count(), scale, sketch })
}

/// All sketch tracks of an artifact (empty for exact-stats runs and
/// pre-sketch artifacts, which have no `sketches` block).
pub(crate) fn sketch_tracks(root: &Json) -> Result<Vec<SketchTrack>> {
    root.get("sketches")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .map(sketch_from)
        .collect()
}

/// A recognized artifact: either a telemetry metrics artifact or a
/// `wienna cluster --stats-json` dump (which has no `schema` key and is
/// recognized structurally). The `--diff` gate accepts both; the
/// renderer only takes metrics artifacts.
pub(crate) enum LoadedArtifact {
    Metrics { root: Json, streamed: bool },
    Stats { root: Json },
}

/// Structural fingerprint of a `--stats-json` dump: the cluster-stats
/// schema has no `schema` key but always carries these counters (pinned
/// by `rust/testdata/cluster_stats_schema.golden`).
fn is_stats_dump(root: &Json) -> bool {
    root.get("schema").is_none()
        && root.get("arrived").is_some()
        && root.get("completed").is_some()
        && root.get("per_class").is_some()
}

/// Load and classify artifact text — buffered `wienna-metrics-v1` JSON,
/// a `wienna-metrics-stream-v1` JSONL stream (reconstructed first), or
/// a schema-less `--stats-json` dump. Anything else errors naming the
/// schema that was actually detected.
pub(crate) fn load_artifact(artifact: &str) -> Result<LoadedArtifact> {
    let streamed = artifact.starts_with(&format!("{{\"schema\": \"{METRICS_STREAM_SCHEMA}\"}}"));
    let buffered;
    let text = if streamed {
        buffered = crate::telemetry::stream_to_metrics_v1(artifact)
            .context("malformed or truncated wienna-metrics-stream-v1 stream")?;
        &buffered
    } else {
        artifact
    };
    let root = parse_json(text).context("artifact is not valid JSON")?;
    match root.get("schema").and_then(Json::as_str) {
        Some("wienna-metrics-v1") => Ok(LoadedArtifact::Metrics { root, streamed }),
        Some(schema) => bail!(
            "unsupported artifact schema '{schema}' (expected wienna-metrics-v1, a wienna-metrics-stream-v1 stream, or a wienna --stats-json dump)"
        ),
        None if is_stats_dump(&root) => Ok(LoadedArtifact::Stats { root }),
        None => bail!(
            "unsupported artifact schema '<missing>' (expected wienna-metrics-v1, a wienna-metrics-stream-v1 stream, or a wienna --stats-json dump)"
        ),
    }
}

/// [`load_artifact`] restricted to metrics artifacts — the report
/// renderer's loader. Returns `(root, streamed)`; a stats dump errors
/// with the detected schema spelled out (only `report --diff` compares
/// stats dumps, the renderer's sections need telemetry).
pub(crate) fn load_metrics_artifact(artifact: &str) -> Result<(Json, bool)> {
    match load_artifact(artifact)? {
        LoadedArtifact::Metrics { root, streamed } => Ok((root, streamed)),
        LoadedArtifact::Stats { .. } => bail!(
            "unsupported artifact schema: detected a wienna --stats-json cluster-stats dump; `wienna report` renders wienna-metrics-v1 artifacts (use `report --diff`, which accepts stats dumps)"
        ),
    }
}

/// Render the full report from artifact text (buffered JSON or JSONL
/// stream) plus an optional Chrome trace. Pure string-to-string so the
/// tests can pin the output without touching the filesystem.
pub fn render_report(artifact: &str, trace: Option<&str>, top: usize) -> Result<String> {
    let (root, streamed) = load_metrics_artifact(artifact)?;

    let mut out = String::new();
    let requests = root.num("requests").unwrap_or(0.0) as u64;
    let epochs = root.get("epochs").and_then(Json::as_arr).unwrap_or(&[]);
    out.push_str(&format!(
        "artifact: wienna-metrics-v1{} | {requests} completed requests | {} epoch samples\n\n",
        if streamed { " (reconstructed from wienna-metrics-stream-v1 stream)" } else { "" },
        epochs.len()
    ));
    if requests == 0 && epochs.is_empty() {
        // A run that recorded nothing is a valid artifact, not an
        // analyzer error: say so explicitly instead of leaving the
        // reader to infer it from a page of zeros and dashes.
        out.push_str("verdict: no traffic recorded (0 completed requests, 0 epoch samples)\n\n");
    }

    // Percentile table, re-estimated from the exported buckets. Tracks
    // with an ε-bounded quantile sketch in the artifact (bounded-stats
    // runs) are answered from the sketch at stats-line resolution and
    // marked; the rest fall back to the power-of-two histogram buckets.
    let sketches = sketch_tracks(&root)?;
    let mut sketch_eps: Option<f64> = None;
    let mut t = Table::new(
        "latency / queue-wait / batch percentiles (histogram-estimated)",
        &["track", "count", "p50", "p95", "p99", "mean"],
    );
    for hj in root.get("histograms").and_then(Json::as_arr).unwrap_or(&[]) {
        let (name, h) = histogram_from(hj)?;
        if let Some(sk) = sketches.iter().find(|s| s.name == name && s.count > 0) {
            sketch_eps = Some(sk.eps());
            t.row(vec![
                format!("{name} (sketch)"),
                sk.count.to_string(),
                cell(Some(sk.quantile(50.0))),
                cell(Some(sk.quantile(95.0))),
                cell(Some(sk.quantile(99.0))),
                cell(Some(sk.mean())),
            ]);
            continue;
        }
        if h.count == 0 {
            continue;
        }
        t.row(vec![
            name,
            h.count.to_string(),
            cell(Some(h.quantile(50.0))),
            cell(Some(h.quantile(95.0))),
            cell(Some(h.quantile(99.0))),
            cell(Some(h.mean())),
        ]);
    }
    if t.rows.is_empty() {
        t.row(vec!["(no samples)".into(), "0".into(), "-".into(), "-".into(), "-".into(), "-".into()]);
    }
    out.push_str(&t.render());
    out.push_str("(estimates are within one power-of-two bucket of the exact rank: est/exact in (1/2, 2])\n");
    if let Some(eps) = sketch_eps {
        out.push_str(&format!(
            "(tracks marked (sketch) use the run's ε-bounded quantile sketch: relative error <= {eps})\n"
        ));
    }
    out.push('\n');

    // Phase-attribution bottleneck verdict.
    let mut best: Option<(&str, f64)> = None;
    let mut frac_line = String::new();
    for name in PHASES {
        let v = root.num(&format!("{name}_frac"));
        if !frac_line.is_empty() {
            frac_line.push_str("  ");
        }
        frac_line.push_str(&format!("{name} {}", cell(v)));
        if let Some(v) = v {
            if best.is_none() || v > best.expect("checked").1 {
                best = Some((name, v));
            }
        }
    }
    out.push_str(&format!("phase attribution (fraction of completed-request cycles): {frac_line}\n"));
    match best {
        Some((name, v)) => {
            out.push_str(&format!("bottleneck verdict: {name} ({:.1}% of cycles)", v * 100.0));
            if root.get("dist_alarm") == Some(&Json::Bool(true)) {
                out.push_str(" | DIST ALARM: shared wireless medium is the bottleneck");
            }
            out.push('\n');
        }
        None => out.push_str("bottleneck verdict: no completed requests\n"),
    }
    out.push('\n');

    // SLO burn-rate alarm timeline.
    match root.get("slo") {
        Some(slo) => {
            let raised = slo.num("alerts_raised").unwrap_or(0.0) as u64;
            let cleared = slo.num("alerts_cleared").unwrap_or(0.0) as u64;
            out.push_str(&format!(
                "slo burn-rate alerts: {raised} raised, {cleared} cleared, {} still active\n",
                raised.saturating_sub(cleared)
            ));
            let events = slo.get("events").and_then(Json::as_arr).unwrap_or(&[]);
            if !events.is_empty() {
                let mut t = Table::new(
                    "alarm timeline",
                    &["epoch", "cycle", "class", "window", "event", "burn rate"],
                );
                for e in events {
                    t.row(vec![
                        cell(e.num("epoch")),
                        cell(e.num("cycle")),
                        e.get("class").and_then(Json::as_str).unwrap_or("-").to_string(),
                        e.get("window").and_then(Json::as_str).unwrap_or("-").to_string(),
                        e.get("kind").and_then(Json::as_str).unwrap_or("-").to_string(),
                        cell(e.num("burn_rate")),
                    ]);
                }
                out.push_str(&t.render());
            }
        }
        None => out.push_str("slo burn-rate alerts: not recorded (pre-slo artifact)\n"),
    }
    out.push('\n');

    // Per-package MAC occupancy at the last barrier, hottest first.
    if let Some(last) = epochs.last() {
        let occ = last.get("mac_occupancy_by_pkg").and_then(Json::as_arr).unwrap_or(&[]);
        let wait = last.get("token_wait_by_pkg").and_then(Json::as_arr).unwrap_or(&[]);
        if !occ.is_empty() {
            let mut rows: Vec<(usize, f64, f64)> = occ
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    (
                        i,
                        v.as_f64().unwrap_or(f64::NAN),
                        wait.get(i).and_then(Json::as_f64).unwrap_or(f64::NAN),
                    )
                })
                .collect();
            rows.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            let shown = rows.len().min(top.max(1));
            let mut t = Table::new(
                &format!(
                    "top {shown} of {} packages by MAC occupancy (last barrier, shard-major index)",
                    rows.len()
                ),
                &["package", "mac occupancy", "token wait (cycles)"],
            );
            for &(i, o, w) in rows.iter().take(shown) {
                t.row(vec![format!("pkg{i}"), cell(Some(o)), cell(Some(w))]);
            }
            out.push_str(&t.render());
            out.push('\n');
        }
    }

    // Optional Chrome-trace census.
    if let Some(trace_text) = trace {
        let tj = parse_json(trace_text).context("trace file is not valid JSON")?;
        let events = tj.get("traceEvents").and_then(Json::as_arr).unwrap_or(&[]);
        let count = |ph: &str| {
            events.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph)).count()
        };
        out.push_str(&format!(
            "trace: {} events | {} request slices, {} instants, {} counter samples, {} flow arrows, {} metadata rows\n",
            events.len(),
            count("X"),
            count("i"),
            count("C"),
            count("s") + count("f"),
            count("M"),
        ));
    }
    Ok(out)
}

/// CLI entry: `wienna report <metrics.json|.jsonl> [--trace FILE] [--top N]`.
pub fn run(args: &[String]) -> Result<()> {
    let path = args.first().context("report needs an artifact path")?;
    let mut trace_path: Option<&String> = None;
    let mut top = 8usize;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--trace" => {
                trace_path = Some(args.get(i + 1).context("--trace needs a file")?);
                i += 2;
            }
            "--top" => {
                let v = args.get(i + 1).context("--top needs a number")?;
                top = v
                    .parse()
                    .map_err(|_| crate::anyhow::Error::msg(format!("--top: bad number '{v}'")))?;
                i += 2;
            }
            other => bail!("unknown report flag '{other}' (expected --trace FILE or --top N)"),
        }
    }
    let artifact =
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let trace = match trace_path {
        Some(p) => Some(std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?),
        None => None,
    };
    print!("{}", render_report(&artifact, trace.as_deref(), top)?);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parser_round_trips_the_shapes_the_emitters_use() {
        let doc = r#"{
  "schema": "x",
  "n": 3.5,
  "neg": -2e3,
  "flag": true,
  "nothing": null,
  "arr": [1, 2, { "exp": null, "count": 1 }],
  "text": "a\"b\\c\nd"
}"#;
        let j = parse_json(doc).expect("valid doc");
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("x"));
        assert_eq!(j.num("n"), Some(3.5));
        assert_eq!(j.num("neg"), Some(-2000.0));
        assert_eq!(j.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
        let arr = j.get("arr").and_then(Json::as_arr).expect("arr");
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("exp"), Some(&Json::Null));
        assert_eq!(j.get("text").and_then(Json::as_str), Some("a\"b\\c\nd"));
        assert!(parse_json("{").is_err());
        assert!(parse_json("{} trailing").is_err());
        assert!(parse_json("{\"a\": nope}").is_err());
    }

    fn sample_artifact() -> String {
        let mut t = crate::telemetry::Telemetry::default();
        for v in [1.0, 2.0, 4.0, 8.0, 100.0] {
            t.metrics.latency_ms.record(v);
        }
        t.metrics.epochs.push(crate::telemetry::EpochSample {
            epoch: 1,
            cycle: 5000.0,
            completed: 5,
            mac_occupancy_by_pkg: vec![0.1, 0.9, 0.4],
            token_wait_by_pkg: vec![0.0, 120.0, 30.0],
            ..Default::default()
        });
        t.metrics.slo_events.push(crate::telemetry::SloEvent {
            epoch: 1,
            cycle: 5000.0,
            class: crate::cluster::TrafficClass::Interactive,
            window: crate::telemetry::SloWindow::Fast,
            kind: crate::telemetry::SloEventKind::Raise,
            burn_rate: 12.0,
        });
        let mut attr = crate::telemetry::PhaseTotals::default();
        attr.requests = 5;
        attr.queue = 10.0;
        attr.dist = 70.0;
        attr.compute = 20.0;
        crate::telemetry::metrics_json(&t, &attr, None, None)
    }

    #[test]
    fn report_renders_every_section_from_the_artifact_alone() {
        let s = render_report(&sample_artifact(), None, 2).expect("well-formed artifact");
        assert!(s.contains("artifact: wienna-metrics-v1 | 5 completed requests | 1 epoch samples"));
        assert!(s.contains("latency_ms"), "percentile table row:\n{s}");
        assert!(s.contains("bottleneck verdict: dist (70.0% of cycles)"));
        assert!(s.contains("DIST ALARM"), "70% dist must carry the alarm:\n{s}");
        assert!(s.contains("slo burn-rate alerts: 1 raised, 0 cleared, 1 still active"));
        assert!(s.contains("alarm timeline"));
        assert!(s.contains("top 2 of 3 packages"));
        let pkg1 = s.find("pkg1").expect("hottest package listed");
        let pkg2 = s.find("pkg2").expect("runner-up listed");
        assert!(pkg1 < pkg2, "sorted hottest-first");
        assert!(!s.contains("pkg0"), "--top 2 drops the coolest package");
    }

    #[test]
    fn report_reads_a_stream_identically_to_the_buffered_artifact() {
        // Round-trip the buffered artifact through the streaming format:
        // the report must not care which one it was handed.
        let buffered = sample_artifact();
        let from_buffered = render_report(&buffered, None, 8).expect("buffered");

        // Re-emit as a stream: pull the epochs back out via the parser.
        let mut t = crate::telemetry::Telemetry::default();
        for v in [1.0, 2.0, 4.0, 8.0, 100.0] {
            t.metrics.latency_ms.record(v);
        }
        t.metrics.epochs.push(crate::telemetry::EpochSample {
            epoch: 1,
            cycle: 5000.0,
            completed: 5,
            mac_occupancy_by_pkg: vec![0.1, 0.9, 0.4],
            token_wait_by_pkg: vec![0.0, 120.0, 30.0],
            ..Default::default()
        });
        t.metrics.slo_events.push(crate::telemetry::SloEvent {
            epoch: 1,
            cycle: 5000.0,
            class: crate::cluster::TrafficClass::Interactive,
            window: crate::telemetry::SloWindow::Fast,
            kind: crate::telemetry::SloEventKind::Raise,
            burn_rate: 12.0,
        });
        let mut attr = crate::telemetry::PhaseTotals::default();
        attr.requests = 5;
        attr.queue = 10.0;
        attr.dist = 70.0;
        attr.compute = 20.0;
        let mut sink: Vec<u8> = Vec::new();
        let mut w = crate::telemetry::MetricsStreamWriter::new(&mut sink);
        for e in &t.metrics.epochs {
            w.write_epoch(e);
        }
        w.write_summary(&crate::telemetry::metrics_json_summary(&t, &attr, None, None));
        w.finish().expect("Vec sink");
        let stream = String::from_utf8(sink).expect("utf8");

        let from_stream = render_report(&stream, None, 8).expect("streamed");
        assert!(from_stream.contains("reconstructed from wienna-metrics-stream-v1"));
        assert_eq!(
            from_stream.replace(" (reconstructed from wienna-metrics-stream-v1 stream)", ""),
            from_buffered,
            "same artifact, same report"
        );
    }

    #[test]
    fn report_handles_a_zero_request_artifact_with_an_explicit_verdict() {
        let t = crate::telemetry::Telemetry::default();
        let artifact = crate::telemetry::metrics_json(
            &t,
            &crate::telemetry::PhaseTotals::default(),
            None,
            None,
        );
        let s = render_report(&artifact, None, 8).expect("a no-traffic artifact is not an error");
        assert!(s.contains("0 completed requests | 0 epoch samples"));
        assert!(s.contains("verdict: no traffic recorded"), "explicit no-traffic verdict:\n{s}");
        assert!(s.contains("(no samples)"), "empty percentile table renders zeros/dashes");
        assert!(s.contains("bottleneck verdict: no completed requests"));
    }

    #[test]
    fn report_rejects_foreign_schemas_and_counts_trace_events() {
        let err = render_report("{\"schema\": \"something-else\"}\n", None, 8).unwrap_err();
        assert!(err.to_string().contains("unsupported artifact schema"));

        let trace = "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n\
                     {\"name\":\"a\",\"ph\":\"X\",\"pid\":0,\"tid\":0,\"ts\":0,\"dur\":1},\n\
                     {\"name\":\"b\",\"ph\":\"C\",\"pid\":0,\"tid\":0,\"ts\":0}\n]}\n";
        let s = render_report(&sample_artifact(), Some(trace), 8).expect("with trace");
        assert!(s.contains("trace: 2 events | 1 request slices, 0 instants, 1 counter samples"));
    }

    #[test]
    fn report_prefers_sketch_tracks_over_histogram_buckets() {
        // A bounded-stats artifact: the latency_ms histogram rides along
        // as usual, but the ε-bounded sketch (recorded in cycles) must
        // win the percentile table at stats-line resolution.
        let mut t = crate::telemetry::Telemetry::default();
        let mut sk = crate::telemetry::QuantileSketch::new(0.01);
        for v in [1.0, 2.0, 4.0, 8.0, 100.0] {
            t.metrics.latency_ms.record(v);
            sk.record(crate::serve::ms_to_cycles(v));
        }
        let mut attr = crate::telemetry::PhaseTotals::default();
        attr.requests = 5;
        attr.compute = 100.0;
        let sketches = vec![("latency_ms".to_string(), &sk)];
        let artifact = crate::telemetry::metrics_json_with(&t, &attr, None, None, &sketches);
        assert!(artifact.contains("\"sketches\": ["), "sketch block exported:\n{artifact}");

        let s = render_report(&artifact, None, 8).expect("bounded artifact");
        assert!(s.contains("latency_ms (sketch)"), "sketch track preferred:\n{s}");
        assert!(s.contains("ε-bounded quantile sketch"), "resolution footnote:\n{s}");

        // The rebuilt sketch answers the same quantiles (in ms) the live
        // one does — the export must be lossless.
        let (root, _) = load_metrics_artifact(&artifact).expect("loads");
        let tracks = sketch_tracks(&root).expect("parses");
        assert_eq!(tracks.len(), 1);
        for p in [50.0, 95.0, 99.0] {
            let want = crate::serve::cycles_to_ms(sk.quantile(p));
            let got = tracks[0].quantile(p);
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "p{p}: rebuilt {got} vs live {want}"
            );
        }
    }

    #[test]
    fn report_names_a_stats_dump_when_handed_one() {
        let stats = crate::cluster::ClusterStats::default().to_json();
        let err = render_report(&stats, None, 8).unwrap_err().to_string();
        assert!(err.contains("stats-json"), "error names the detected schema: {err}");
        assert!(err.contains("report --diff"), "error points at the gate that accepts it: {err}");

        let err = render_report("{\"arrived\": 1}\n", None, 8).unwrap_err().to_string();
        assert!(err.contains("'<missing>'"), "schema-less non-stats object: {err}");
    }
}
