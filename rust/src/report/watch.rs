//! `wienna watch <tcp://HOST:PORT | FILE.jsonl | ->` — a refreshing
//! text dashboard rendered from a `wienna-metrics-stream-v1` stream
//! alone, no re-simulation and no access to the producing process.
//!
//! Sources:
//!
//! * `tcp://HOST:PORT` — **listen** on the address and accept one
//!   connection; the simulator side connects out with
//!   `--metrics-out tcp://HOST:PORT`, so the dashboard starts first;
//! * `-` — read the stream from stdin (`wienna cluster ... --metrics-out -
//!   | wienna watch -`);
//! * any other argument — a `.jsonl` stream file (replays it).
//!
//! Each `epoch_sample` line refreshes the dashboard: instantaneous
//! goodput (Δcompleted over the epoch window), queue/in-flight/power
//! gauges, the top-N packages by MAC occupancy, and the active SLO
//! alerts tracked from `slo_event` raise/clear lines. Percentiles and
//! phase fractions come only from the final `summary` line — until it
//! arrives they render as "(pending summary)". The screen is cleared
//! between frames only when stdout is a terminal (`--no-clear` forces
//! append mode).
//!
//! A TCP dashboard is **long-lived**: when a stream finishes (summary
//! line or disconnect), the listener goes back to accepting, with the
//! dashboard state reset for the new run — so one `wienna watch` pane
//! survives back-to-back simulations. `--once` restores the original
//! serve-one-connection-then-exit behavior for scripting.
//!
//! `--raw` echoes the received lines verbatim to stdout instead of
//! rendering — the capture half of CI's loopback smoke test, which
//! asserts the bytes that crossed the socket are identical to the
//! stream file the same configuration writes. A raw capture is a
//! one-shot byte-for-byte artifact, so `--raw` implies `--once`
//! (appending a second run's bytes would corrupt the capture).

use std::collections::BTreeSet;
use std::io::{BufRead, BufReader, IsTerminal, Write};

use crate::anyhow::{bail, Context, Result};
use crate::report::artifact::{histogram_from, parse_json, Json};
use crate::serve::cycles_to_ms;
use crate::telemetry::{METRICS_STREAM_SCHEMA, PHASES};

/// Default number of packages shown in the MAC-occupancy leaderboard.
const DEFAULT_TOP: usize = 4;

/// Everything the dashboard knows, folded from the stream so far.
#[derive(Default)]
struct DashState {
    epochs: u64,
    /// The most recent `epoch_sample` object.
    last: Option<Json>,
    /// Δcompleted / Δwall between the last two samples, in req/s.
    goodput_rps: f64,
    slo_raised: u64,
    slo_cleared: u64,
    /// Currently-raised alerts as "class/window" keys, sorted.
    active_alerts: BTreeSet<String>,
    /// The parsed final summary artifact, once it has arrived.
    summary: Option<Json>,
}

impl DashState {
    fn ingest_epoch(&mut self, e: &Json) {
        if let Some(prev) = &self.last {
            let dc = e.num("completed").unwrap_or(0.0) - prev.num("completed").unwrap_or(0.0);
            let dt_ms =
                cycles_to_ms(e.num("cycle").unwrap_or(f64::NAN) - prev.num("cycle").unwrap_or(f64::NAN));
            self.goodput_rps = if dt_ms > 0.0 { dc / dt_ms * 1000.0 } else { f64::NAN };
        }
        self.epochs += 1;
        self.last = Some(e.clone());
    }

    fn ingest_slo(&mut self, e: &Json) {
        let key = format!(
            "{}/{}",
            e.get("class").and_then(Json::as_str).unwrap_or("?"),
            e.get("window").and_then(Json::as_str).unwrap_or("?")
        );
        match e.get("kind").and_then(Json::as_str) {
            Some("raise") => {
                self.slo_raised += 1;
                self.active_alerts.insert(key);
            }
            _ => {
                self.slo_cleared += 1;
                self.active_alerts.remove(&key);
            }
        }
    }
}

fn gauge(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x:.3}"),
        _ => "-".to_string(),
    }
}

/// Render one dashboard frame. Pure state-to-string so the unit tests
/// can pin frames without a terminal or a socket.
fn render_dashboard(state: &DashState, top: usize) -> String {
    let mut out = String::new();
    match &state.last {
        Some(e) => {
            out.push_str(&format!(
                "wienna watch | epoch {} @ cycle {}\n",
                e.num("epoch").unwrap_or(0.0),
                gauge(e.num("cycle"))
            ));
            let goodput = if state.epochs >= 2 && state.goodput_rps.is_finite() {
                format!("{:.1} req/s", state.goodput_rps)
            } else {
                "(one sample)".to_string()
            };
            out.push_str(&format!(
                "goodput {goodput} | completed {} | queued {} | in-flight {} | power {} W\n",
                e.num("completed").unwrap_or(0.0),
                e.num("queued").unwrap_or(0.0),
                e.num("in_flight_batches").unwrap_or(0.0),
                gauge(e.num("power_w"))
            ));
            let occ = e.get("mac_occupancy_by_pkg").and_then(Json::as_arr).unwrap_or(&[]);
            if occ.is_empty() {
                out.push_str("mac occupancy: (no per-package gauges)\n");
            } else {
                let mut rows: Vec<(usize, f64)> = occ
                    .iter()
                    .enumerate()
                    .map(|(i, v)| (i, v.as_f64().unwrap_or(f64::NAN)))
                    .collect();
                rows.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
                let shown = rows.len().min(top.max(1));
                out.push_str(&format!("mac occupancy top {shown} of {}:", rows.len()));
                for &(i, o) in rows.iter().take(shown) {
                    out.push_str(&format!("  pkg{i} {}", gauge(Some(o))));
                }
                out.push('\n');
            }
        }
        None => out.push_str("wienna watch | waiting for the first epoch sample\n"),
    }
    out.push_str(&format!(
        "slo alerts: {} raised, {} cleared | active: {}\n",
        state.slo_raised,
        state.slo_cleared,
        if state.active_alerts.is_empty() {
            "none".to_string()
        } else {
            state.active_alerts.iter().cloned().collect::<Vec<_>>().join(", ")
        }
    ));
    match &state.summary {
        Some(root) => {
            out.push_str("percentiles (summary):\n");
            for hj in root.get("histograms").and_then(Json::as_arr).unwrap_or(&[]) {
                if let Ok((name, h)) = histogram_from(hj) {
                    if h.count == 0 {
                        continue;
                    }
                    out.push_str(&format!(
                        "  {name}: n={} p50 {} p95 {} p99 {}\n",
                        h.count,
                        gauge(Some(h.quantile(50.0))),
                        gauge(Some(h.quantile(95.0))),
                        gauge(Some(h.quantile(99.0)))
                    ));
                }
            }
            let mut frac_line = String::new();
            for name in PHASES {
                if !frac_line.is_empty() {
                    frac_line.push_str("  ");
                }
                frac_line.push_str(&format!("{name} {}", gauge(root.num(&format!("{name}_frac")))));
            }
            out.push_str(&format!("phase fractions: {frac_line}\n"));
            out.push_str("stream complete\n");
        }
        None => out.push_str("percentiles / phase fractions: (pending summary)\n"),
    }
    out
}

/// Echo one stream's lines verbatim to stdout (the `--raw` capture).
fn capture_raw(reader: Box<dyn BufRead>) -> Result<()> {
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for line in reader.lines() {
        let line = line.context("reading stream")?;
        writeln!(out, "{line}").context("writing captured line")?;
    }
    out.flush().context("flushing capture")
}

/// Render one stream's dashboard to completion: header check, then a
/// redraw per line until the summary (or EOF on a truncated stream).
/// State is local, so every stream — in particular every reconnect of a
/// long-lived TCP dashboard — starts from a blank slate.
fn serve_dashboard(reader: Box<dyn BufRead>, top: usize, no_clear: bool) -> Result<()> {
    let mut lines = reader.lines();
    let header = lines.next().context("empty stream")?.context("reading stream header")?;
    if header != format!("{{\"schema\": \"{METRICS_STREAM_SCHEMA}\"}}") {
        bail!("not a {METRICS_STREAM_SCHEMA} stream (header line: {header})");
    }
    let clear = !no_clear && std::io::stdout().is_terminal();
    let mut state = DashState::default();
    let redraw = |state: &DashState| {
        let frame = render_dashboard(state, top);
        let stdout = std::io::stdout();
        let mut out = stdout.lock();
        if clear {
            let _ = out.write_all(b"\x1b[2J\x1b[H");
        }
        let _ = out.write_all(frame.as_bytes());
        let _ = out.flush();
    };
    for line in lines {
        let line = line.context("reading stream")?;
        if line.is_empty() {
            continue;
        }
        let j = parse_json(&line).context("malformed stream line")?;
        if let Some(e) = j.get("epoch_sample") {
            state.ingest_epoch(e);
            redraw(&state);
        } else if let Some(e) = j.get("slo_event") {
            state.ingest_slo(e);
            redraw(&state);
        } else if let Some(s) = j.get("summary").and_then(Json::as_str) {
            state.summary = Some(parse_json(s).context("malformed summary payload")?);
            redraw(&state);
            return Ok(());
        } else {
            bail!("unknown stream line shape: {line}");
        }
    }
    // EOF without a summary: a truncated (still-running or killed)
    // stream. The frames already rendered are still the live view.
    eprintln!("watch: stream ended without a summary line (truncated stream)");
    Ok(())
}

/// CLI entry: `wienna watch <tcp://HOST:PORT | FILE.jsonl | ->
/// [--top N] [--raw] [--no-clear] [--once]`.
pub fn run(args: &[String]) -> Result<()> {
    let mut source: Option<&String> = None;
    let mut top = DEFAULT_TOP;
    let mut raw = false;
    let mut no_clear = false;
    let mut once = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--top" => {
                let v = args.get(i + 1).context("--top needs a number")?;
                top = v
                    .parse()
                    .map_err(|_| crate::anyhow::Error::msg(format!("--top: bad number '{v}'")))?;
                i += 2;
            }
            "--raw" => {
                raw = true;
                i += 1;
            }
            "--no-clear" => {
                no_clear = true;
                i += 1;
            }
            "--once" => {
                once = true;
                i += 1;
            }
            other if other.starts_with("--") => {
                bail!(
                    "unknown watch flag '{other}' (expected --top N, --raw, --no-clear or --once)"
                )
            }
            _ if source.is_none() => {
                source = Some(&args[i]);
                i += 1;
            }
            other => bail!("watch takes one source, got a second: '{other}'"),
        }
    }
    let source =
        source.context("watch needs a source: tcp://HOST:PORT, a .jsonl file, or '-'")?;

    // Status chatter goes to stderr so `--raw` stdout stays a clean
    // byte-for-byte capture of the stream.
    if let Some(addr) = source.strip_prefix("tcp://") {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("binding watch listener on {addr}"))?;
        eprintln!("watch: listening on {addr} — start the run with --metrics-out {source}");
        // A raw capture is a one-shot byte-for-byte artifact: appending a
        // second run's bytes (header line included) would corrupt it.
        let once = once || raw;
        loop {
            let (conn, peer) = listener.accept().context("accepting the stream connection")?;
            eprintln!("watch: stream connected from {peer}");
            let reader: Box<dyn BufRead> = Box::new(BufReader::new(conn));
            if raw {
                capture_raw(reader)?;
            } else {
                serve_dashboard(reader, top, no_clear)?;
            }
            if once {
                return Ok(());
            }
            eprintln!("watch: run finished — listening on {addr} for the next one");
        }
    }
    let reader: Box<dyn BufRead> = if source == "-" {
        Box::new(BufReader::new(std::io::stdin()))
    } else {
        Box::new(BufReader::new(
            std::fs::File::open(source).with_context(|| format!("opening {source}"))?,
        ))
    };
    if raw {
        capture_raw(reader)
    } else {
        serve_dashboard(reader, top, no_clear)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{
        metrics_json_summary, EpochSample, MetricsStreamWriter, PhaseTotals, Telemetry,
    };

    fn sample(epoch: u64, cycle: f64, completed: u64) -> String {
        let mut t = Telemetry::default();
        t.metrics.epochs.push(EpochSample {
            epoch,
            cycle,
            completed,
            queued: 3,
            in_flight_batches: 2,
            mac_occupancy_by_pkg: vec![0.1, 0.9, 0.4],
            token_wait_by_pkg: vec![0.0, 1.0, 2.0],
            ..Default::default()
        });
        let mut sink: Vec<u8> = Vec::new();
        let mut w = MetricsStreamWriter::new(&mut sink);
        w.write_epoch(&t.metrics.epochs[0]);
        w.finish().expect("Vec sink");
        let s = String::from_utf8(sink).expect("utf8");
        s.lines().nth(1).expect("epoch line").to_string()
    }

    fn ingest_line(state: &mut DashState, line: &str) {
        let j = parse_json(line).expect("valid line");
        if let Some(e) = j.get("epoch_sample") {
            state.ingest_epoch(e);
        } else if let Some(e) = j.get("slo_event") {
            state.ingest_slo(e);
        } else if let Some(s) = j.get("summary").and_then(Json::as_str) {
            state.summary = Some(parse_json(s).expect("valid summary"));
        } else {
            panic!("unknown line {line}");
        }
    }

    #[test]
    fn dashboard_tracks_goodput_occupancy_and_alerts_from_lines_alone() {
        let mut state = DashState::default();
        let first = render_dashboard(&state, 4);
        assert!(first.contains("waiting for the first epoch sample"));

        ingest_line(&mut state, &sample(0, 0.0, 0));
        ingest_line(&mut state, &sample(1, 1_000_000.0, 500));
        ingest_line(
            &mut state,
            "{\"slo_event\": { \"epoch\": 1, \"cycle\": 1000000, \"class\": \"interactive\", \
             \"window\": \"fast\", \"kind\": \"raise\", \"burn_rate\": 12 }}",
        );
        let frame = render_dashboard(&state, 2);
        assert!(frame.contains("epoch 1 @ cycle 1000000"), "frame:\n{frame}");
        assert!(frame.contains("goodput"), "frame:\n{frame}");
        assert!(!frame.contains("(one sample)"), "two samples give a rate:\n{frame}");
        assert!(frame.contains("completed 500"));
        // Top-2 of 3 packages, hottest first; the coolest is dropped.
        assert!(frame.contains("mac occupancy top 2 of 3:  pkg1 0.900  pkg2 0.400"));
        assert!(frame.contains("slo alerts: 1 raised, 0 cleared | active: interactive/fast"));
        assert!(frame.contains("(pending summary)"));

        ingest_line(
            &mut state,
            "{\"slo_event\": { \"epoch\": 2, \"cycle\": 2000000, \"class\": \"interactive\", \
             \"window\": \"fast\", \"kind\": \"clear\", \"burn_rate\": 0.5 }}",
        );
        let frame = render_dashboard(&state, 2);
        assert!(frame.contains("slo alerts: 1 raised, 1 cleared | active: none"));
    }

    #[test]
    fn dashboard_renders_percentiles_once_the_summary_arrives() {
        let mut t = Telemetry::default();
        for v in [1.0, 2.0, 4.0, 8.0] {
            t.metrics.latency_ms.record(v);
        }
        let mut attr = PhaseTotals::default();
        attr.requests = 4;
        attr.compute = 80.0;
        attr.queue = 20.0;
        let summary = metrics_json_summary(&t, &attr, None, None);
        let mut sink: Vec<u8> = Vec::new();
        let mut w = MetricsStreamWriter::new(&mut sink);
        w.write_summary(&summary);
        w.finish().expect("Vec sink");
        let stream = String::from_utf8(sink).expect("utf8");
        let summary_line = stream.lines().nth(1).expect("summary line");

        let mut state = DashState::default();
        ingest_line(&mut state, summary_line);
        let frame = render_dashboard(&state, 4);
        assert!(frame.contains("latency_ms: n=4"), "frame:\n{frame}");
        assert!(frame.contains("phase fractions: queue 0.200"), "frame:\n{frame}");
        assert!(frame.contains("stream complete"));
        assert!(!frame.contains("(pending summary)"));
    }

    #[test]
    fn tcp_listener_accepts_back_to_back_runs() {
        // Regression: `wienna watch tcp://...` used to serve exactly one
        // connection and exit. Without `--once` the listener must go
        // back to accepting after a stream finishes, so a long-lived
        // dashboard survives consecutive simulations.
        use std::io::Write as _;
        let port = 17_941u16;
        let args: Vec<String> = vec![format!("tcp://127.0.0.1:{port}"), "--no-clear".into()];
        std::thread::spawn(move || {
            let _ = run(&args);
        });
        let header = format!("{{\"schema\": \"{METRICS_STREAM_SCHEMA}\"}}");
        for attempt_run in 0..2 {
            let mut conn = None;
            for _ in 0..100 {
                match std::net::TcpStream::connect(("127.0.0.1", port)) {
                    Ok(c) => {
                        conn = Some(c);
                        break;
                    }
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
                }
            }
            let mut conn = conn.unwrap_or_else(|| {
                panic!("run {attempt_run}: the watch listener stopped accepting")
            });
            // A header-only stream: the dashboard treats EOF as a
            // truncated run and goes back to listening.
            writeln!(conn, "{header}").expect("writing stream header");
        }
    }
}
