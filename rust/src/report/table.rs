//! Minimal ASCII table / CSV renderer.

/// Column-aligned ASCII table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table { title: title.to_string(), header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for plotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV next to the repo's bench outputs.
    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format a float with engineering-style precision.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xx".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("a   bbbb"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "1".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(12345.6), "12346");
        assert_eq!(fmt(42.42), "42.4");
        assert_eq!(fmt(1.234), "1.234");
    }
}
