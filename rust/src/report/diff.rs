//! `wienna report --diff A B` — the regression gate: compare two
//! artifacts (buffered `wienna-metrics-v1` JSON,
//! `wienna-metrics-stream-v1` JSONL, or a schema-less `wienna cluster
//! --stats-json` dump, mixed freely) and exit nonzero when the second
//! one regressed past tolerance. CI points it at a known-good baseline
//! artifact and the candidate run's artifact; a clean exit means "no
//! regression within tolerance". Stats dumps gate on the dimensions
//! they carry (goodput, percentiles, phase fractions, SLO totals);
//! the event timeline and occupancy gauges compare as absent.
//!
//! Gated dimensions, each with its own knob:
//!
//! * **percentiles** — p50/p95/p99 per shared histogram track,
//!   re-estimated from the exported buckets; one-sided (only a *rise*
//!   beyond `--tolerance`, a relative fraction, regresses — latency
//!   falling is an improvement, not a failure);
//! * **goodput** — completed-request count falling more than the same
//!   relative tolerance;
//! * **phase attribution** — any phase fraction shifting more than
//!   `--phase-tolerance` (absolute) in either direction, plus the
//!   `dist_alarm` flag newly tripping;
//! * **SLO alert timeline** — total raises growing, broken down per
//!   class/window pair;
//! * **per-package MAC occupancy** — any package at the last epoch
//!   barrier shifting more than `--occupancy-tolerance` (absolute).
//!
//! Two zero-traffic artifacts compare clean with an explicit "no
//! traffic" note; traffic in the baseline but none in the candidate is
//! itself a regression.

use std::collections::BTreeMap;

use crate::anyhow::{bail, Context, Result};
use crate::report::artifact::{
    histogram_from, load_artifact, sketch_tracks, Json, LoadedArtifact,
};
use crate::report::table::fmt;
use crate::report::Table;
use crate::telemetry::PHASES;

/// Default relative tolerance on percentile / goodput deltas (10%).
pub const DEFAULT_TOLERANCE: f64 = 0.1;
/// Default absolute tolerance on phase-fraction shifts.
pub const DEFAULT_PHASE_TOLERANCE: f64 = 0.05;
/// Default absolute tolerance on per-package MAC-occupancy shifts.
pub const DEFAULT_OCCUPANCY_TOLERANCE: f64 = 0.10;

/// One percentile track, already reduced to the three gated stats —
/// the common denominator of every artifact kind the gate accepts
/// (sketch-resolution when the artifact carries a sketch, histogram
/// buckets otherwise, exact stats-line values from a `--stats-json`
/// dump). A `NaN` entry means the artifact doesn't carry that stat for
/// this track; the comparison skips it.
struct Track {
    name: String,
    count: u64,
    /// p50, p95, p99 — display units (ms for the latency tracks).
    p: [f64; 3],
}

const TRACK_STATS: [(&str, f64); 3] = [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)];

/// Everything the gate compares, pulled out of one parsed artifact.
struct Facts {
    requests: f64,
    tracks: Vec<Track>,
    /// Phase fractions in [`PHASES`] order (`None` when exported null).
    fracs: Vec<Option<f64>>,
    slo_raised: u64,
    slo_cleared: u64,
    /// Raise counts per "class/window" key, iteration-stable.
    slo_raises_by_key: BTreeMap<String, u64>,
    /// `mac_occupancy_by_pkg` at the last epoch barrier.
    occupancy: Vec<f64>,
    dist_alarm: bool,
}

fn facts(artifact: &str) -> Result<Facts> {
    match load_artifact(artifact)? {
        LoadedArtifact::Metrics { root, .. } => metrics_facts(&root),
        LoadedArtifact::Stats { root } => Ok(stats_facts(&root)),
    }
}

fn metrics_facts(root: &Json) -> Result<Facts> {
    // Prefer the ε-bounded sketch for a track when the artifact carries
    // one (bounded-stats runs) — same resolution the stats line had —
    // and fall back to the power-of-two histogram estimate otherwise.
    let sketches = sketch_tracks(root)?;
    let mut tracks = Vec::new();
    for hj in root.get("histograms").and_then(Json::as_arr).unwrap_or(&[]) {
        let (name, h) = histogram_from(hj)?;
        if let Some(sk) = sketches.iter().find(|s| s.name == name && s.count > 0) {
            tracks.push(Track {
                name,
                count: sk.count,
                p: TRACK_STATS.map(|(_, p)| sk.quantile(p)),
            });
        } else {
            tracks.push(Track {
                name,
                count: h.count,
                p: TRACK_STATS.map(|(_, p)| h.quantile(p)),
            });
        }
    }
    let fracs = PHASES.iter().map(|n| root.num(&format!("{n}_frac"))).collect();
    let (slo_raised, slo_cleared, slo_raises_by_key) = match root.get("slo") {
        Some(slo) => {
            let mut by_key: BTreeMap<String, u64> = BTreeMap::new();
            for e in slo.get("events").and_then(Json::as_arr).unwrap_or(&[]) {
                if e.get("kind").and_then(Json::as_str) == Some("raise") {
                    let key = format!(
                        "{}/{}",
                        e.get("class").and_then(Json::as_str).unwrap_or("?"),
                        e.get("window").and_then(Json::as_str).unwrap_or("?")
                    );
                    *by_key.entry(key).or_insert(0) += 1;
                }
            }
            (
                slo.num("alerts_raised").unwrap_or(0.0) as u64,
                slo.num("alerts_cleared").unwrap_or(0.0) as u64,
                by_key,
            )
        }
        None => (0, 0, BTreeMap::new()),
    };
    let occupancy = root
        .get("epochs")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .last()
        .and_then(|e| e.get("mac_occupancy_by_pkg"))
        .and_then(Json::as_arr)
        .map(|a| a.iter().map(|v| v.as_f64().unwrap_or(f64::NAN)).collect())
        .unwrap_or_default();
    Ok(Facts {
        requests: root.num("requests").unwrap_or(0.0),
        tracks,
        fracs,
        slo_raised,
        slo_cleared,
        slo_raises_by_key,
        occupancy,
        dist_alarm: root.get("dist_alarm") == Some(&Json::Bool(true)),
    })
}

/// Facts from a `wienna cluster --stats-json` dump: the latency
/// percentiles are the run's exact (or ε-bounded) stats-line values,
/// the fleet track is named `latency_ms` and the per-class tracks
/// `latency_ms_<class>` so they line up with the metrics artifact's
/// histogram/sketch track names when the two kinds are diffed against
/// each other. The dump has no event timeline or occupancy gauges, so
/// those dimensions compare as absent.
fn stats_facts(root: &Json) -> Facts {
    let completed = root.num("completed").unwrap_or(0.0);
    let mut tracks = vec![Track {
        name: "latency_ms".to_string(),
        count: completed as u64,
        p: [
            root.num("p50_ms").unwrap_or(f64::NAN),
            root.num("p95_ms").unwrap_or(f64::NAN),
            root.num("p99_ms").unwrap_or(f64::NAN),
        ],
    }];
    for c in root.get("per_class").and_then(Json::as_arr).unwrap_or(&[]) {
        let Some(label) = c.get("class").and_then(Json::as_str) else { continue };
        tracks.push(Track {
            name: format!("latency_ms_{}", label.replace('-', "_")),
            count: c.num("completed").unwrap_or(0.0) as u64,
            p: [
                c.num("p50_ms").unwrap_or(f64::NAN),
                f64::NAN, // per-class p95 is not in the stats schema
                c.num("p99_ms").unwrap_or(f64::NAN),
            ],
        });
    }
    let raised = root.num("slo_alerts_raised").unwrap_or(0.0) as u64;
    let active = root.num("slo_alerts_active").unwrap_or(0.0) as u64;
    Facts {
        requests: completed,
        tracks,
        fracs: PHASES.iter().map(|n| root.num(&format!("{n}_frac"))).collect(),
        slo_raised: raised,
        slo_cleared: raised.saturating_sub(active),
        slo_raises_by_key: BTreeMap::new(),
        occupancy: Vec::new(),
        dist_alarm: false,
    }
}

fn pct(rel: f64) -> String {
    format!("{:+.1}%", rel * 100.0)
}

/// Compare two artifacts (text in, report + violation count out). Pure
/// string-to-string so the tests can pin verdicts without touching the
/// filesystem; [`run`] layers file I/O and the nonzero exit on top.
pub fn diff_artifacts(
    a: &str,
    b: &str,
    tol: f64,
    phase_tol: f64,
    occ_tol: f64,
) -> Result<(String, usize)> {
    let fa = facts(a).context("artifact A")?;
    let fb = facts(b).context("artifact B")?;
    let mut out = String::new();
    let mut violations: Vec<String> = Vec::new();

    out.push_str(&format!(
        "diff: A ({} completed requests) vs B ({} completed requests)\n",
        fa.requests, fb.requests
    ));
    out.push_str(&format!(
        "tolerances: percentiles/goodput {:.1}% relative, phase fractions {} absolute, occupancy {} absolute\n\n",
        tol * 100.0,
        fmt(phase_tol),
        fmt(occ_tol)
    ));

    if fa.requests == 0.0 && fb.requests == 0.0 {
        out.push_str("verdict: no traffic in either artifact — nothing to compare\n");
        return Ok((out, 0));
    }
    if fa.requests > 0.0 && fb.requests == 0.0 {
        violations.push(format!(
            "B completed no requests while A completed {} (traffic vanished)",
            fa.requests
        ));
    } else if fa.requests > 0.0 {
        let rel = (fb.requests - fa.requests) / fa.requests;
        if rel < -tol {
            violations.push(format!(
                "completed requests fell {} (tolerance {:.1}%)",
                pct(rel),
                tol * 100.0
            ));
        }
    }

    // Percentile deltas per shared track, one-sided on rises. Tracks
    // carry sketch-resolution values when the artifact exported a
    // sketch, histogram estimates otherwise, and exact stats-line
    // values for --stats-json dumps — the comparison is agnostic.
    let mut t = Table::new(
        "percentile deltas (B vs A)",
        &["track", "stat", "A", "B", "delta", "flag"],
    );
    for ta in &fa.tracks {
        let Some(tb) = fb.tracks.iter().find(|t| t.name == ta.name) else { continue };
        if ta.count == 0 || tb.count == 0 {
            continue;
        }
        for (i, (label, _)) in TRACK_STATS.iter().enumerate() {
            let va = ta.p[i];
            let vb = tb.p[i];
            if !(va.is_finite() && vb.is_finite() && va > 0.0) {
                continue;
            }
            let rel = (vb - va) / va;
            let flagged = rel > tol;
            if flagged {
                violations.push(format!(
                    "{} {label} rose {} (tolerance {:.1}%)",
                    ta.name,
                    pct(rel),
                    tol * 100.0
                ));
            }
            t.row(vec![
                ta.name.clone(),
                label.to_string(),
                fmt(va),
                fmt(vb),
                pct(rel),
                if flagged { "REGRESSED" } else { "ok" }.to_string(),
            ]);
        }
    }
    if t.rows.is_empty() {
        t.row(vec![
            "(no comparable tracks)".to_string(),
            "-".to_string(),
            "-".to_string(),
            "-".to_string(),
            "-".to_string(),
            "-".to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push('\n');

    // Phase-attribution shifts, two-sided: attribution moving at all
    // means the workload's bottleneck structure changed.
    let mut t = Table::new("phase attribution shifts", &["phase", "A", "B", "delta", "flag"]);
    for (i, name) in PHASES.iter().enumerate() {
        match (fa.fracs[i], fb.fracs[i]) {
            (Some(va), Some(vb)) if va.is_finite() && vb.is_finite() => {
                let d = vb - va;
                let flagged = d.abs() > phase_tol;
                if flagged {
                    violations.push(format!(
                        "{name} fraction shifted {:+.3} (tolerance {})",
                        d,
                        fmt(phase_tol)
                    ));
                }
                t.row(vec![
                    name.to_string(),
                    fmt(va),
                    fmt(vb),
                    format!("{d:+.3}"),
                    if flagged { "SHIFTED" } else { "ok" }.to_string(),
                ]);
            }
            _ => t.row(vec![
                name.to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ]),
        }
    }
    out.push_str(&t.render());
    if !fa.dist_alarm && fb.dist_alarm {
        violations
            .push("dist alarm newly tripped: the shared wireless medium became the bottleneck".to_string());
        out.push_str("dist alarm: A clear -> B TRIPPED\n");
    } else {
        out.push_str(&format!(
            "dist alarm: A {} -> B {}\n",
            if fa.dist_alarm { "tripped" } else { "clear" },
            if fb.dist_alarm { "tripped" } else { "clear" }
        ));
    }
    out.push('\n');

    // SLO alert timeline: total raises growing is a regression; the
    // per-class/window breakdown says where.
    out.push_str(&format!(
        "slo alerts: A {} raised / {} cleared | B {} raised / {} cleared\n",
        fa.slo_raised, fa.slo_cleared, fb.slo_raised, fb.slo_cleared
    ));
    if fb.slo_raised > fa.slo_raised {
        violations.push(format!(
            "slo alerts raised grew {} -> {}",
            fa.slo_raised, fb.slo_raised
        ));
    }
    let mut keys: Vec<&String> =
        fa.slo_raises_by_key.keys().chain(fb.slo_raises_by_key.keys()).collect();
    keys.sort();
    keys.dedup();
    for key in keys {
        let na = fa.slo_raises_by_key.get(key).copied().unwrap_or(0);
        let nb = fb.slo_raises_by_key.get(key).copied().unwrap_or(0);
        if na != nb {
            out.push_str(&format!("  {key}: {na} -> {nb} raises\n"));
        }
    }
    out.push('\n');

    // Per-package MAC occupancy at the last barrier, absolute shifts.
    let n = fa.occupancy.len().max(fb.occupancy.len());
    if n > 0 {
        let mut t = Table::new(
            "per-package MAC occupancy deltas (last barrier)",
            &["package", "A", "B", "delta", "flag"],
        );
        for i in 0..n {
            let va = fa.occupancy.get(i).copied().unwrap_or(f64::NAN);
            let vb = fb.occupancy.get(i).copied().unwrap_or(f64::NAN);
            let d = vb - va;
            let flagged = d.is_finite() && d.abs() > occ_tol;
            if flagged {
                violations.push(format!(
                    "pkg{i} MAC occupancy shifted {d:+.3} (tolerance {})",
                    fmt(occ_tol)
                ));
            }
            t.row(vec![
                format!("pkg{i}"),
                if va.is_finite() { fmt(va) } else { "-".to_string() },
                if vb.is_finite() { fmt(vb) } else { "-".to_string() },
                if d.is_finite() { format!("{d:+.3}") } else { "-".to_string() },
                if flagged { "SHIFTED" } else { "ok" }.to_string(),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }

    if violations.is_empty() {
        out.push_str("verdict: no regression (within tolerance)\n");
    } else {
        out.push_str(&format!("verdict: {} tolerance violation(s)\n", violations.len()));
        for v in &violations {
            out.push_str(&format!("  regression: {v}\n"));
        }
    }
    Ok((out, violations.len()))
}

fn flag_f64(args: &[String], i: usize, name: &str) -> Result<f64> {
    let v = args.get(i + 1).with_context(|| format!("{name} needs a number"))?;
    v.parse::<f64>()
        .ok()
        .filter(|x| x.is_finite() && *x >= 0.0)
        .with_context(|| format!("{name}: bad value '{v}' (expected a non-negative number)"))
}

/// CLI entry: `wienna report --diff A B [--tolerance F]
/// [--phase-tolerance F] [--occupancy-tolerance F]` — `F` values are
/// fractions (0.1 = 10%). Exits nonzero (via `Err`) when any tolerance
/// is exceeded, so CI can gate directly on the exit status.
pub fn run(args: &[String]) -> Result<()> {
    let mut paths: Vec<&String> = Vec::new();
    let mut tol = DEFAULT_TOLERANCE;
    let mut phase_tol = DEFAULT_PHASE_TOLERANCE;
    let mut occ_tol = DEFAULT_OCCUPANCY_TOLERANCE;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--tolerance" => {
                tol = flag_f64(args, i, "--tolerance")?;
                i += 2;
            }
            "--phase-tolerance" => {
                phase_tol = flag_f64(args, i, "--phase-tolerance")?;
                i += 2;
            }
            "--occupancy-tolerance" => {
                occ_tol = flag_f64(args, i, "--occupancy-tolerance")?;
                i += 2;
            }
            other if other.starts_with("--") => {
                bail!("unknown report --diff flag '{other}' (expected --tolerance F, --phase-tolerance F or --occupancy-tolerance F)")
            }
            _ => {
                paths.push(&args[i]);
                i += 1;
            }
        }
    }
    let &[a_path, b_path] = paths.as_slice() else {
        bail!("report --diff needs exactly two artifact paths (got {})", paths.len())
    };
    let a = std::fs::read_to_string(a_path).with_context(|| format!("reading {a_path}"))?;
    let b = std::fs::read_to_string(b_path).with_context(|| format!("reading {b_path}"))?;
    let (report, violations) = diff_artifacts(&a, &b, tol, phase_tol, occ_tol)?;
    print!("{report}");
    if violations > 0 {
        bail!("regression: {violations} tolerance violation(s) between {a_path} and {b_path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TrafficClass;
    use crate::telemetry::{
        metrics_json, EpochSample, PhaseTotals, SloEvent, SloEventKind, SloWindow, Telemetry,
    };

    /// Build an artifact whose latency track holds `latencies`, with
    /// `dist`/`compute` phase weight and one epoch of occupancy gauges.
    fn artifact(latencies: &[f64], dist: f64, compute: f64, occ: &[f64], raises: usize) -> String {
        let mut t = Telemetry::default();
        for &v in latencies {
            t.metrics.latency_ms.record(v);
        }
        t.metrics.epochs.push(EpochSample {
            epoch: 0,
            cycle: 5000.0,
            completed: latencies.len() as u64,
            mac_occupancy_by_pkg: occ.to_vec(),
            token_wait_by_pkg: vec![0.0; occ.len()],
            ..Default::default()
        });
        for i in 0..raises {
            t.metrics.slo_events.push(SloEvent {
                epoch: i as u64,
                cycle: 1000.0 * i as f64,
                class: TrafficClass::Interactive,
                window: SloWindow::Fast,
                kind: SloEventKind::Raise,
                burn_rate: 10.0,
            });
        }
        let mut attr = PhaseTotals::default();
        attr.requests = latencies.len() as u64;
        attr.dist = dist;
        attr.compute = compute;
        metrics_json(&t, &attr, None, None)
    }

    #[test]
    fn identical_artifacts_diff_clean() {
        let a = artifact(&[1.0, 2.0, 4.0, 8.0], 20.0, 80.0, &[0.3, 0.5], 0);
        let (report, violations) = diff_artifacts(&a, &a, 0.1, 0.05, 0.1).expect("valid");
        assert_eq!(violations, 0, "identical artifacts must gate clean:\n{report}");
        assert!(report.contains("verdict: no regression (within tolerance)"));
        assert!(report.contains("latency_ms"));
    }

    #[test]
    fn latency_blowup_phase_shift_and_alerts_all_gate() {
        let a = artifact(&[1.0, 1.0, 2.0, 2.0], 20.0, 80.0, &[0.3, 0.5], 0);
        // B: 8x the latency, dist-dominated (trips the alarm), an SLO
        // raise, and pkg0 occupancy up by 0.4.
        let b = artifact(&[8.0, 8.0, 16.0, 16.0], 70.0, 30.0, &[0.7, 0.5], 1);
        let (report, violations) = diff_artifacts(&a, &b, 0.1, 0.05, 0.1).expect("valid");
        assert!(violations > 0, "the regressed artifact must trip the gate:\n{report}");
        assert!(report.contains("REGRESSED"), "percentile rise flagged:\n{report}");
        assert!(report.contains("dist alarm: A clear -> B TRIPPED"));
        assert!(report.contains("slo alerts raised grew 0 -> 1") || report.contains("regression: slo alerts raised grew 0 -> 1"));
        assert!(report.contains("interactive/fast: 0 -> 1 raises"));
        assert!(report.contains("pkg0"), "occupancy delta table present:\n{report}");
    }

    #[test]
    fn improvements_do_not_gate() {
        let a = artifact(&[8.0, 8.0, 16.0, 16.0], 20.0, 80.0, &[0.5], 0);
        let b = artifact(&[1.0, 1.0, 2.0, 2.0], 20.0, 80.0, &[0.5], 0);
        let (report, violations) = diff_artifacts(&a, &b, 0.1, 0.05, 0.1).expect("valid");
        assert_eq!(violations, 0, "faster is not a regression:\n{report}");
    }

    #[test]
    fn vanished_traffic_is_a_regression_and_mutual_silence_is_not() {
        let live = artifact(&[1.0, 2.0], 20.0, 80.0, &[0.5], 0);
        let dead = metrics_json(&Telemetry::default(), &PhaseTotals::default(), None, None);
        let (_, violations) = diff_artifacts(&live, &dead, 0.1, 0.05, 0.1).expect("valid");
        assert!(violations > 0, "traffic vanished entirely");
        let (report, violations) = diff_artifacts(&dead, &dead, 0.1, 0.05, 0.1).expect("valid");
        assert_eq!(violations, 0);
        assert!(report.contains("no traffic in either artifact"));
    }

    #[test]
    fn stats_json_dumps_diff_against_each_other_and_against_metrics() {
        // Two hand-built stats dumps: B's p99 is 4x A's. The gate must
        // accept the schema-less stats format and flag the rise.
        let dump = |p50: f64, p99: f64| {
            let mut s = crate::cluster::ClusterStats::default().to_json();
            s = s.replace("\"completed\": 0", "\"completed\": 100");
            s = s.replace("\"p50_ms\": 0", &format!("\"p50_ms\": {p50}"));
            s = s.replace("\"p95_ms\": 0", &format!("\"p95_ms\": {}", p99 * 0.8));
            s.replace("\"p99_ms\": 0", &format!("\"p99_ms\": {p99}"))
        };
        let a = dump(1.0, 2.0);
        let b = dump(1.0, 8.0);
        let (report, violations) = diff_artifacts(&a, &b, 0.1, 0.05, 0.1).expect("stats accepted");
        assert!(violations > 0, "4x p99 rise must gate:\n{report}");
        assert!(report.contains("latency_ms p99 rose"), "named violation:\n{report}");
        let (_, clean) = diff_artifacts(&a, &a, 0.1, 0.05, 0.1).expect("valid");
        assert_eq!(clean, 0, "identical dumps gate clean");

        // Mixed kinds: a metrics artifact vs a stats dump share the
        // latency_ms track, so the comparison still lands.
        let m = artifact(&[1.0, 1.0, 2.0, 2.0], 20.0, 80.0, &[0.5], 0);
        let (report, _) = diff_artifacts(&m, &b, 10.0, 10.0, 10.0).expect("mixed kinds accepted");
        assert!(report.contains("latency_ms"), "shared track compared:\n{report}");
    }

    #[test]
    fn unknown_schemas_still_error_with_the_detected_name() {
        let err =
            diff_artifacts("{\"schema\": \"what\"}\n", "{\"schema\": \"what\"}\n", 0.1, 0.05, 0.1)
                .unwrap_err()
                .to_string();
        assert!(err.contains("artifact A"), "which side failed: {err}");
    }

    #[test]
    fn sketch_tracks_sharpen_the_percentile_gate() {
        // Same distribution in both sketches -> identical quantiles,
        // zero delta, clean gate even at a 1% tolerance (histogram
        // estimates could wobble a whole power-of-two bucket).
        let bounded = |vals: &[f64]| {
            let mut t = Telemetry::default();
            let mut sk = crate::telemetry::QuantileSketch::new(0.01);
            for &v in vals {
                t.metrics.latency_ms.record(v);
                sk.record(crate::serve::ms_to_cycles(v));
            }
            let mut attr = PhaseTotals::default();
            attr.requests = vals.len() as u64;
            attr.compute = 100.0;
            let sketches = vec![("latency_ms".to_string(), &sk)];
            crate::telemetry::metrics_json_with(&t, &attr, None, None, &sketches)
        };
        let a = bounded(&[1.0, 2.0, 4.0, 8.0]);
        let (report, violations) = diff_artifacts(&a, &a, 0.01, 0.05, 0.1).expect("valid");
        assert_eq!(violations, 0, "identical sketches gate clean at 1%:\n{report}");

        let b = bounded(&[4.0, 8.0, 16.0, 32.0]);
        let (report, violations) = diff_artifacts(&a, &b, 0.1, 0.05, 0.1).expect("valid");
        assert!(violations > 0, "4x shift through the sketch path:\n{report}");
    }

    #[test]
    fn tolerance_knob_widens_the_gate() {
        let a = artifact(&[1.0, 1.0, 2.0, 2.0], 20.0, 80.0, &[0.5], 0);
        // 4x rise — two full power-of-two buckets, so the histogram
        // estimate resolves it regardless of in-bucket interpolation.
        let b = artifact(&[4.0, 4.0, 8.0, 8.0], 20.0, 80.0, &[0.5], 0);
        let (report, strict) = diff_artifacts(&a, &b, 0.1, 0.05, 0.1).expect("valid");
        assert!(strict > 0, "10% tolerance must flag a 4x rise:\n{report}");
        let (_, loose) = diff_artifacts(&a, &b, 10.0, 0.05, 0.1).expect("valid");
        assert_eq!(loose, 0, "a 10x tolerance swallows it");
    }
}
