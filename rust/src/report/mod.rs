//! Rendering layer (substrate S12): ASCII tables and CSV series used by
//! the benchmark harnesses to print paper-figure-shaped output, plus
//! the offline artifact analyzers — `wienna report` ([`artifact`]),
//! the `--diff` regression gate ([`diff`]) and the live stream
//! dashboard `wienna watch` ([`watch`]).

pub mod artifact;
pub mod diff;
pub mod table;
pub mod watch;

pub use table::Table;
