//! Rendering layer (substrate S12): ASCII tables and CSV series used by
//! the benchmark harnesses to print paper-figure-shaped output.

pub mod artifact;
pub mod table;

pub use table::Table;
