//! Compile-only stub of the `xla` PJRT bindings.
//!
//! The build is fully offline, so the real `xla` crate (and the PJRT
//! shared library behind it) cannot be a dependency. Historically that
//! meant the `pjrt`-gated code — `runtime`, `coordinator::exec`, the
//! `e2e` targets — could silently bit-rot: nothing ever type-checked it.
//! This module closes that hole (ROADMAP item): it mirrors exactly the
//! slice of the `xla` API surface the crate uses, with every constructor
//! failing at *runtime* with a clear message. CI runs
//! `cargo check --features pjrt --all-targets` against it.
//!
//! To run the real numerics path, enable the `xla-backend` feature (which
//! suppresses this stub) and add the actual dependency:
//! `xla = { git = "https://github.com/LaurentMazare/xla-rs" }`.

use std::fmt;

/// Stub error: carries the "backend not vendored" message. Implements
/// `std::error::Error` so `?` and `.context(..)` flow into the crate's
/// `anyhow` shim exactly as the real crate's errors would.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the real `xla` PJRT bindings are not vendored in this offline build; \
         enable the `xla-backend` feature and add the `xla` dependency (see Cargo.toml) \
         to run the pjrt path"
    ))
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}
