//! Design-space search (substrate S14): the fleet auto-sizer.
//!
//! The ROADMAP's follow-on to the serving subsystem: given a target SLO
//! and a target load, search the space of buildable packages — design
//! point (wireless vs interposer × conservative vs aggressive), chiplet
//! count, PEs per chiplet, per-chiplet buffer — and fleet widths for the
//! *cheapest* fleet whose simulated p99 latency meets the SLO. This is
//! the WIENNA co-design loop run in reverse: instead of fixing hardware
//! and measuring throughput (Fig 7), fix the service objective and let
//! the fast cost engine pick the hardware.
//!
//! The search is only tractable because of the cost engine's hot-path
//! work in this crate: candidate characterization leans on the
//! crate-level layer memo (`cost::memo`), fans out over a scoped worker
//! pool (`cost::par`), and the final feasibility proof of each surviving
//! candidate is a short discrete-event `serve` replay rather than an
//! analytic guess.
//!
//! * [`space`] — candidate enumeration ([`SearchSpace`] →
//!   [`PackagePoint`]) and the relative dollar [`CostModel`];
//! * [`autosize`] — dominance pruning, fleet-width bisection over serve
//!   probes, and the [`AutosizeResult`] report. With
//!   [`MultiClassSlo`](autosize::MultiClassSlo) set, probes run on the
//!   sharded `cluster` engine and feasibility means every traffic class
//!   meets its own p99 target (an SLO *vector* instead of one number).
//!   Every probe also meters energy (`wienna::power`), and the result
//!   carries the (dollar cost × energy/request × p99) non-dominated
//!   front — `wienna search --pareto` — with the cheapest-only answer
//!   always a member of it.
//!
//! ## Example
//!
//! ```no_run
//! use wienna::search::{autosize, AutosizeConfig, CostModel, SearchSpace};
//! use wienna::serve::WorkloadMix;
//!
//! // Cheapest fleet that serves the canonical CNN+transformer mix at
//! // 3000 req/s with a 25 ms p99.
//! let cfg = AutosizeConfig::new(25.0, 3000.0, WorkloadMix::cnn_transformer_default());
//! let result = autosize(&cfg, &SearchSpace::default(), &CostModel::default());
//! if let Some(best) = &result.best {
//!     println!(
//!         "{} x{} | cost {:.0} | p99 {:.2} ms",
//!         best.point.label(),
//!         best.width,
//!         best.fleet_cost,
//!         best.p99_ms
//!     );
//! }
//! ```

pub mod autosize;
pub mod space;

pub use autosize::{
    autosize, AutosizeConfig, AutosizeResult, CandidateEval, ClassSlo, FleetPlan, MultiClassSlo,
    PROBE_BATCHES,
};
pub use space::{CostModel, PackagePoint, SearchSpace};
