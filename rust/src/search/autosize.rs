//! The fleet auto-sizer: find the cheapest fleet meeting an SLO at a load.
//!
//! Pipeline per search:
//!
//! 1. **Enumerate** every [`PackagePoint`] of the [`SearchSpace`].
//! 2. **Characterize** each candidate analytically (parallel over
//!    candidates, memo-backed): its batch-latency curve at probe batch
//!    sizes for every model of the mix, its mix-weighted single-package
//!    capacity, and whether a lone idle package can meet each model's SLO
//!    at batch 1 at all.
//! 3. **Prune** infeasible candidates and dominated ones — a candidate
//!    whose package costs at least as much as another's while being
//!    pointwise no faster across the whole probed latency curve can never
//!    anchor a cheaper feasible fleet (more chiplets never raises
//!    per-batch latency, so the curves order cleanly along that axis).
//! 4. **Bisect** each survivor's fleet width on short discrete-event
//!    `serve` replays until the simulated p99 meets the SLO, and return
//!    the cheapest such fleet.

use super::space::{CostModel, PackagePoint, SearchSpace};
use crate::cluster::{Cluster, ClusterConfig, TrafficClass};
use crate::config::CLOCK_HZ;
use crate::cost::{par, CostEngine};
use crate::serve::{ms_to_cycles, CostCache, Fleet, RoutePolicy, ServeStats, Source, WorkloadMix};

/// Batch sizes at which candidate latency curves are probed — the dynamic
/// batcher's full default candidate ladder (`BatcherConfig::default`), so
/// the dominance check sees exactly the frontier the serve loop will use
/// and latency-curve crossings between ladder rungs cannot hide from it.
pub const PROBE_BATCHES: [u64; 6] = [1, 2, 4, 8, 16, 32];

/// One class's p99 target in the multi-class sizing mode.
#[derive(Debug, Clone, Copy)]
pub struct ClassSlo {
    pub class: TrafficClass,
    /// p99 latency target for this class, in milliseconds.
    pub p99_ms: f64,
}

/// Multi-class sizing mode: probes run on the sharded `cluster` engine
/// under this tenant population, and a fleet is feasible only when
/// **every** listed class meets its own p99 target — the SLO is a vector,
/// not a single fleet-level number.
#[derive(Debug, Clone)]
pub struct MultiClassSlo {
    /// Per-class p99 targets. A class that received no traffic in a probe
    /// trivially meets its target.
    pub targets: Vec<ClassSlo>,
    /// Cluster configuration of each probe (classes, admission,
    /// preemption, shards). Probe threads are forced to 1 — candidates
    /// already fan out over the search's own worker pool.
    pub cluster: ClusterConfig,
}

impl MultiClassSlo {
    /// The default tenant population with explicit per-class targets
    /// (interactive / batch / best-effort, in that order).
    pub fn with_targets(interactive_ms: f64, batch_ms: f64, best_effort_ms: f64) -> Self {
        MultiClassSlo {
            targets: vec![
                ClassSlo { class: TrafficClass::Interactive, p99_ms: interactive_ms },
                ClassSlo { class: TrafficClass::Batch, p99_ms: batch_ms },
                ClassSlo { class: TrafficClass::BestEffort, p99_ms: best_effort_ms },
            ],
            cluster: ClusterConfig::default(),
        }
    }
}

/// What the auto-sizer is asked for.
#[derive(Debug, Clone)]
pub struct AutosizeConfig {
    /// Fleet-level p99 target, in milliseconds (ignored when
    /// `class_slos` switches the search to the multi-class mode).
    pub slo_ms: f64,
    /// Offered load the fleet must absorb, in requests/second.
    pub load_rps: f64,
    /// Traffic mix (each entry carries its own per-request deadline).
    pub mix: WorkloadMix,
    /// Simulated horizon of each serve probe, in milliseconds.
    pub horizon_ms: f64,
    /// Seed for the probes' Poisson arrivals (same for every candidate,
    /// so fleets are compared on identical traffic).
    pub seed: u64,
    /// Worker threads for candidate characterization and bisection.
    pub threads: usize,
    /// Disable dominance pruning (exhaustive mode; tests compare the two).
    pub prune: bool,
    /// Multi-class mode: size against a per-class SLO vector on the
    /// sharded cluster engine instead of a single fleet-level p99 on
    /// `serve::Fleet` probes.
    pub class_slos: Option<MultiClassSlo>,
}

impl AutosizeConfig {
    pub fn new(slo_ms: f64, load_rps: f64, mix: WorkloadMix) -> Self {
        AutosizeConfig {
            slo_ms,
            load_rps,
            mix,
            horizon_ms: 40.0,
            seed: 42,
            threads: par::num_threads(),
            prune: true,
            class_slos: None,
        }
    }
}

/// Analytic characterization of one candidate (search stage 2).
#[derive(Debug, Clone)]
pub struct CandidateEval {
    pub point: PackagePoint,
    pub package_cost: f64,
    /// Pipelined batch latency in cycles at every (mix entry × probe
    /// batch), in mix-major order — the dominance-check curve.
    pub latency_curve: Vec<f64>,
    /// Mix-weighted best-case sustainable throughput of ONE package
    /// (requests/second): per mix entry, the *lowest* cycles/request over
    /// the probed batch ladder. An upper bound on real capacity — the
    /// batcher may dispatch any rung — so widths derived from it are true
    /// lower bounds for the bisection.
    pub capacity_rps: f64,
    /// Whether a lone idle package meets every mix entry's deadline at
    /// batch 1. If not, no fleet of this package ever meets the SLO.
    pub feasible_alone: bool,
}

/// One sized fleet with its simulated serving quality.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    pub point: PackagePoint,
    pub width: u64,
    pub fleet_cost: f64,
    pub p99_ms: f64,
    pub goodput_rps: f64,
    pub violation_rate: f64,
    /// Whole-run energy per completed request from the probe's meter
    /// (`wienna::power`), in joules — the third Pareto axis. `NaN` when
    /// the probe completed nothing.
    pub energy_per_req_j: f64,
    /// Per-class p99 latencies from the cluster probe (`NaN` for a class
    /// with no completions; empty in single-class mode).
    pub class_p99_ms: Vec<(TrafficClass, f64)>,
    /// Whether every class SLO target was met (`None` in single-class
    /// mode, where feasibility is `p99_ms <= slo_ms`).
    pub meets_class_slos: Option<bool>,
}

/// Outcome of one auto-sizing search.
#[derive(Debug, Clone)]
pub struct AutosizeResult {
    /// Cheapest fleet meeting the SLO, if any candidate produced one.
    pub best: Option<FleetPlan>,
    /// Package points enumerated (the design points explored).
    pub explored: usize,
    /// Candidates discarded before simulation (infeasible or dominated).
    pub pruned: usize,
    /// Discrete-event serve probes executed across all bisections.
    pub simulated_runs: usize,
    /// Every survivor's best fleet, cheapest first.
    pub plans: Vec<FleetPlan>,
    /// The non-dominated subset of `plans` over (dollar cost,
    /// energy/request, p99), cheapest first (`wienna search --pareto`).
    /// `best` is always a member: the plan sort breaks cost ties by p99
    /// then energy, so the cheapest plan cannot be dominated.
    pub pareto: Vec<FleetPlan>,
}

/// Characterize one candidate analytically. All cost-model work funnels
/// through the serve [`CostCache`] and, underneath it, the crate-level
/// layer memo — across 256 candidates most layer shapes repeat.
fn characterize(point: &PackagePoint, cfg: &AutosizeConfig, costs: &CostModel) -> CandidateEval {
    let engine = CostEngine::for_design_point(&point.sys(), point.dp);
    let mut cache = CostCache::new();
    let mut latency_curve = Vec::with_capacity(cfg.mix.entries.len() * PROBE_BATCHES.len());
    let mut feasible_alone = true;
    let weight_total: f64 = cfg.mix.entries.iter().map(|e| e.weight).sum();
    let mut cycles_per_req = 0.0;
    for e in &cfg.mix.entries {
        // Best amortization this package can reach for this model across
        // the batcher's ladder (usually the largest batch, but pipelining
        // and buffer effects can make the curve non-trivial).
        let mut best_cycles_per_req = f64::INFINITY;
        for &b in &PROBE_BATCHES {
            let c = cache.get(&engine, point.dp, e.kind, b, point.local_buffer_bytes);
            latency_curve.push(c.latency);
            if b == 1 && c.latency > e.slo_cycles {
                feasible_alone = false;
            }
            best_cycles_per_req = best_cycles_per_req.min(c.latency / b as f64);
        }
        cycles_per_req += (e.weight / weight_total) * best_cycles_per_req;
    }
    CandidateEval {
        point: *point,
        package_cost: costs.package_cost(point),
        latency_curve,
        capacity_rps: CLOCK_HZ / cycles_per_req,
        feasible_alone,
    }
}

/// `true` if `b` dominates `a`: costs no more and is pointwise no slower
/// across the probed latency curve. Any fleet feasible around `a` is then
/// feasible no wider around `b`, at no higher cost.
fn dominates(b: &CandidateEval, a: &CandidateEval) -> bool {
    b.package_cost <= a.package_cost
        && b.latency_curve.len() == a.latency_curve.len()
        && b.latency_curve.iter().zip(&a.latency_curve).all(|(lb, la)| lb <= la)
}

/// Run one serve probe: `width` packages of `point` under the configured
/// Poisson load. Single-class mode replays on a `serve::Fleet` with EDF
/// routing; multi-class mode replays on the sharded `cluster` engine and
/// scores every class's p99 against its target.
fn probe(point: &PackagePoint, width: u64, cfg: &AutosizeConfig, costs: &CostModel) -> FleetPlan {
    match &cfg.class_slos {
        None => {
            let mut fleet = Fleet::new(point.fleet(width), RoutePolicy::EarliestDeadline);
            let mut source = Source::poisson(cfg.mix.clone(), cfg.load_rps, cfg.seed);
            let mut stats = ServeStats::new();
            fleet.run(&mut source, ms_to_cycles(cfg.horizon_ms), &mut stats);
            FleetPlan {
                point: *point,
                width,
                fleet_cost: costs.fleet_cost(point, width),
                p99_ms: stats.latency_ms(99.0),
                goodput_rps: stats.goodput_rps(),
                violation_rate: stats.violation_rate(),
                energy_per_req_j: stats
                    .energy
                    .map_or(f64::NAN, |e| e.energy_per_req_j(stats.completed())),
                class_p99_ms: Vec::new(),
                meets_class_slos: None,
            }
        }
        Some(mc) => {
            // Probe threads stay at 1: candidates and bisections already
            // fan out over the search's own worker pool, and nested pools
            // would oversubscribe without changing results (the cluster
            // engine is thread-count deterministic).
            let cluster = Cluster::new(
                point.fleet(width),
                ClusterConfig { threads: 1, ..mc.cluster.clone() },
            );
            let mut source = Source::poisson(cfg.mix.clone(), cfg.load_rps, cfg.seed);
            let stats = cluster.run(&mut source, ms_to_cycles(cfg.horizon_ms));
            let class_p99_ms: Vec<(TrafficClass, f64)> =
                mc.targets.iter().map(|t| (t.class, stats.class_latency_ms(t.class, 99.0))).collect();
            let all_met = mc.targets.iter().all(|t| {
                // An infinite target is explicitly unconstrained, and a
                // class with no traffic at all is trivially met.
                if t.p99_ms.is_infinite() {
                    return true;
                }
                let (arrived, shed) =
                    stats.per_class.get(&t.class).map_or((0, 0), |m| (m.arrived, m.shed));
                // A constrained class is feasible only when the fleet
                // served *all* its offered traffic within target: probes
                // run with admission control on, so deadline shedding
                // would otherwise prune the tail into compliance and an
                // undersized fleet would read as feasible. (A finite
                // target with a NaN p99 — completions exist but not for
                // this class — fails the `<=` as it should.)
                arrived == 0
                    || (shed == 0 && stats.class_latency_ms(t.class, 99.0) <= t.p99_ms)
            });
            FleetPlan {
                point: *point,
                width,
                fleet_cost: costs.fleet_cost(point, width),
                p99_ms: stats.serve.latency_ms(99.0),
                goodput_rps: stats.serve.goodput_rps(),
                violation_rate: stats.serve.violation_rate(),
                energy_per_req_j: stats.energy.energy_per_req_j(stats.serve.completed()),
                class_p99_ms,
                meets_class_slos: Some(all_met),
            }
        }
    }
}

fn meets_slo(plan: &FleetPlan, cfg: &AutosizeConfig) -> bool {
    match plan.meets_class_slos {
        Some(met) => met,
        None => plan.p99_ms <= cfg.slo_ms,
    }
}

/// Find the narrowest feasible fleet of `point` by bisection, plus how
/// many probes it took. Width feasibility is monotone: adding a package
/// never slows any request's service in the simulator.
fn bisect_width(
    eval: &CandidateEval,
    max_width: u64,
    cfg: &AutosizeConfig,
    costs: &CostModel,
) -> (Option<FleetPlan>, usize) {
    // Stability lower bound: below this many packages the offered load
    // exceeds fleet capacity and queues grow without bound.
    let lb = (cfg.load_rps / eval.capacity_rps).ceil().max(1.0) as u64;
    if lb > max_width {
        return (None, 0);
    }
    let mut probes = 0;
    let lo_plan = {
        probes += 1;
        probe(&eval.point, lb, cfg, costs)
    };
    if meets_slo(&lo_plan, cfg) {
        return (Some(lo_plan), probes);
    }
    if lb == max_width {
        return (None, probes);
    }
    probes += 1;
    let hi_plan = probe(&eval.point, max_width, cfg, costs);
    if !meets_slo(&hi_plan, cfg) {
        return (None, probes);
    }
    // Invariant: `lo` infeasible, `hi` feasible (with its plan in hand).
    let (mut lo, mut hi, mut hi_plan) = (lb, max_width, hi_plan);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        probes += 1;
        let mid_plan = probe(&eval.point, mid, cfg, costs);
        if meets_slo(&mid_plan, cfg) {
            hi = mid;
            hi_plan = mid_plan;
        } else {
            lo = mid;
        }
    }
    (Some(hi_plan), probes)
}

/// Search `space` for the cheapest fleet meeting `cfg`'s SLO at its load.
pub fn autosize(cfg: &AutosizeConfig, space: &SearchSpace, costs: &CostModel) -> AutosizeResult {
    let points = space.enumerate();
    let explored = points.len();

    // Stage 2: analytic characterization, parallel over candidates.
    let evals: Vec<CandidateEval> =
        par::par_map_slice(&points, cfg.threads, |p| characterize(p, cfg, costs));

    // Stage 3: drop candidates that can never meet the SLO, then the
    // dominated ones (cheapest-first scan keeps the Pareto frontier).
    // The batch-1-vs-mix-SLO gate assumes arrival deadlines equal the mix
    // SLO; multi-class mode rescales deadlines per class and scores
    // against separate targets, so only the (SLO-agnostic) dominance
    // prune applies there.
    let multi_class = cfg.class_slos.is_some();
    let mut survivors: Vec<&CandidateEval> =
        evals.iter().filter(|e| multi_class || e.feasible_alone).collect();
    if cfg.prune {
        survivors.sort_by(|a, b| {
            a.package_cost
                .partial_cmp(&b.package_cost)
                .expect("package costs are finite")
        });
        // Frontier members cost no more than `cand` thanks to the sort,
        // so a pointwise-no-slower member makes `cand` redundant.
        let mut frontier: Vec<&CandidateEval> = Vec::new();
        for cand in survivors {
            if !frontier.iter().any(|&kept| dominates(kept, cand)) {
                frontier.push(cand);
            }
        }
        survivors = frontier;
    }
    let pruned = explored - survivors.len();

    // Stage 4: size each survivor's fleet on short serve replays.
    let sized: Vec<(Option<FleetPlan>, usize)> =
        par::par_map_slice(&survivors, cfg.threads, |&e| bisect_width(e, space.max_width, cfg, costs));

    let simulated_runs: usize = sized.iter().map(|(_, n)| *n).sum();
    let mut plans: Vec<FleetPlan> = sized.into_iter().filter_map(|(p, _)| p).collect();
    // total_cmp, not partial_cmp: a multi-class plan whose probe saw no
    // traffic at all carries a NaN p99 yet is legitimately feasible (all
    // targets trivially met), and NaN must sort deterministically (last
    // among equal costs) instead of panicking the search. The p99-then-
    // energy tie-break also guarantees plans[0] is Pareto-non-dominated:
    // any dominator would need cost <= the minimum with some strict
    // improvement, which the tie-break order rules out.
    plans.sort_by(|a, b| {
        a.fleet_cost
            .total_cmp(&b.fleet_cost)
            .then(a.p99_ms.total_cmp(&b.p99_ms))
            .then(a.energy_per_req_j.total_cmp(&b.energy_per_req_j))
    });
    // Multi-objective output: the (cost, energy/request, p99) front.
    let points: Vec<[f64; 3]> =
        plans.iter().map(|p| [p.fleet_cost, p.energy_per_req_j, p.p99_ms]).collect();
    let pareto: Vec<FleetPlan> =
        crate::power::pareto_front(&points).into_iter().map(|i| plans[i].clone()).collect();
    AutosizeResult { best: plans.first().cloned(), explored, pruned, simulated_runs, plans, pareto }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{MixEntry, ModelKind};

    fn tiny_cfg(load_rps: f64) -> AutosizeConfig {
        let mix = WorkloadMix::new(vec![MixEntry {
            kind: ModelKind::TinyCnn,
            weight: 1.0,
            slo_cycles: ms_to_cycles(20.0),
        }]);
        AutosizeConfig { horizon_ms: 10.0, threads: 2, ..AutosizeConfig::new(20.0, load_rps, mix) }
    }

    #[test]
    fn finds_a_feasible_fleet_on_the_tiny_space() {
        let cfg = tiny_cfg(2000.0);
        let r = autosize(&cfg, &SearchSpace::tiny(), &CostModel::default());
        assert_eq!(r.explored, 4);
        let best = r.best.expect("tiny space must contain a feasible fleet");
        assert!(best.p99_ms <= cfg.slo_ms, "p99 {:.2} ms vs SLO {} ms", best.p99_ms, cfg.slo_ms);
        assert!(best.width >= 1);
        assert!(best.fleet_cost > 0.0);
        // Plans come back cheapest-first.
        for w in r.plans.windows(2) {
            assert!(w[0].fleet_cost <= w[1].fleet_cost);
        }
    }

    #[test]
    fn pruning_never_changes_the_best_fleet_cost() {
        let cfg = tiny_cfg(1500.0);
        let pruned = autosize(&cfg, &SearchSpace::tiny(), &CostModel::default());
        let exhaustive =
            autosize(&AutosizeConfig { prune: false, ..cfg.clone() }, &SearchSpace::tiny(), &CostModel::default());
        let (p, e) = (
            pruned.best.expect("pruned search found a fleet"),
            exhaustive.best.expect("exhaustive search found a fleet"),
        );
        assert_eq!(p.fleet_cost, e.fleet_cost, "pruning changed the optimum");
        assert_eq!(p.width, e.width);
        assert!(pruned.pruned >= exhaustive.pruned);
    }

    #[test]
    fn impossible_slo_returns_no_plan() {
        let mix = WorkloadMix::new(vec![MixEntry {
            kind: ModelKind::ResNet50,
            weight: 1.0,
            // 1 µs: no package can run ResNet-50 that fast.
            slo_cycles: ms_to_cycles(0.001),
        }]);
        let mut cfg = AutosizeConfig::new(0.001, 100.0, mix);
        cfg.horizon_ms = 5.0;
        cfg.threads = 2;
        let r = autosize(&cfg, &SearchSpace::tiny(), &CostModel::default());
        assert!(r.best.is_none());
        assert_eq!(r.pruned, r.explored, "every candidate is infeasible at batch 1");
        assert_eq!(r.simulated_runs, 0);
    }

    #[test]
    fn multi_class_slo_vector_sizes_a_fleet() {
        let mut cfg = tiny_cfg(1500.0);
        // Generous per-class targets so the tiny space stays feasible:
        // interactive at the base SLO, batch relaxed, best-effort free.
        cfg.class_slos = Some(MultiClassSlo::with_targets(20.0, 80.0, f64::INFINITY));
        let r = autosize(&cfg, &SearchSpace::tiny(), &CostModel::default());
        let best = r.best.expect("tiny space must contain a class-feasible fleet");
        assert_eq!(best.meets_class_slos, Some(true));
        assert_eq!(best.class_p99_ms.len(), 3, "one probed p99 per target class");
        for (class, p99) in &best.class_p99_ms {
            let target = match class {
                TrafficClass::Interactive => 20.0,
                TrafficClass::Batch => 80.0,
                TrafficClass::BestEffort => f64::INFINITY,
            };
            assert!(
                p99.is_nan() || *p99 <= target,
                "{} p99 {:.2} ms vs target {target}",
                class.label(),
                p99
            );
        }
        // An unmeetable interactive target finds nothing.
        cfg.class_slos = Some(MultiClassSlo::with_targets(0.001, 80.0, f64::INFINITY));
        let r = autosize(&cfg, &SearchSpace::tiny(), &CostModel::default());
        assert!(r.best.is_none(), "1 us interactive p99 must be infeasible");
    }

    #[test]
    fn pareto_front_is_non_dominated_and_contains_the_cheapest() {
        let cfg = tiny_cfg(1500.0);
        let r = autosize(&cfg, &SearchSpace::tiny(), &CostModel::default());
        assert!(!r.pareto.is_empty(), "a feasible search has a front");
        let triple = |p: &FleetPlan| [p.fleet_cost, p.energy_per_req_j, p.p99_ms];
        // No front member is dominated by any plan.
        for f in &r.pareto {
            for p in &r.plans {
                assert!(
                    !crate::power::dominates(&triple(p), &triple(f)),
                    "front member {} x{} dominated by {} x{}",
                    f.point.label(),
                    f.width,
                    p.point.label(),
                    p.width
                );
            }
        }
        // The cheapest-only answer is on the front, with probed energy.
        let best = r.best.expect("feasible search");
        assert!(r.pareto.iter().any(|f| triple(f) == triple(&best)));
        assert!(best.energy_per_req_j > 0.0, "probes meter energy");
        // The front is cheapest-first like `plans`.
        for w in r.pareto.windows(2) {
            assert!(w[0].fleet_cost <= w[1].fleet_cost);
        }
    }

    #[test]
    fn dominance_is_reflexive_safe() {
        let cfg = tiny_cfg(1000.0);
        let costs = CostModel::default();
        let p = SearchSpace::tiny().enumerate()[0];
        let e = characterize(&p, &cfg, &costs);
        assert!(dominates(&e, &e), "a candidate trivially dominates itself");
    }
}
