//! The auto-sizer's design space: package candidates, their dollar-cost
//! model, and dominance pruning.
//!
//! A *package point* is one buildable package configuration — design
//! point (NoP kind × aggressiveness), chiplet count, PEs per chiplet and
//! per-chiplet buffer budget. The fleet dimension (how many packages sit
//! behind the router) is searched separately per candidate
//! (`search::autosize`), because feasibility at a load is a property of
//! the whole fleet.

use crate::config::{DesignPoint, SystemConfig};
use crate::energy::area::{PE_AREA_MM2, ROUTER_AREA_MM2, SRAM_AREA_MM2_PER_MIB};
use crate::nop::NopKind;
use crate::serve::PackageSpec;

/// One candidate package configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PackagePoint {
    pub dp: DesignPoint,
    pub num_chiplets: u64,
    pub pes_per_chiplet: u64,
    /// Per-chiplet double-buffer budget for inter-layer pipelining.
    pub local_buffer_bytes: u64,
}

impl PackagePoint {
    /// The package's system configuration (Table-4 defaults for the axes
    /// the search does not vary).
    pub fn sys(&self) -> SystemConfig {
        SystemConfig {
            num_chiplets: self.num_chiplets,
            pes_per_chiplet: self.pes_per_chiplet,
            ..Default::default()
        }
    }

    /// Instantiate this point as a named [`PackageSpec`].
    pub fn spec(&self, name: &str) -> PackageSpec {
        PackageSpec::custom(name, self.sys(), self.dp, self.local_buffer_bytes)
    }

    /// `width` identical packages of this point.
    pub fn fleet(&self, width: u64) -> Vec<PackageSpec> {
        (0..width).map(|i| self.spec(&format!("{}-{i}", self.label()))).collect()
    }

    pub fn label(&self) -> String {
        format!(
            "{}/{}c x {}pe/{}KiB",
            self.dp.label(),
            self.num_chiplets,
            self.pes_per_chiplet,
            self.local_buffer_bytes / 1024
        )
    }
}

/// Relative dollar cost of building packages. Absolute calibration is
/// irrelevant to the search — only ratios steer it — so silicon is priced
/// by *area* at a single [`DOLLARS_PER_MM2`] scale, with the areas taken
/// from the paper's Table-3 breakdown (`energy::area`) instead of round
/// numbers (ROADMAP follow-up): PEs at the Eyeriss-derived per-PE area,
/// buffers at the SRAM area per KiB, the wireless premium at the RX area
/// implied by the paper's "16% of chiplet area" figure. Packaging/test
/// overheads and interposer wiring are not in Table 3 and keep their
/// estimate values.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Cost per PE (compute silicon).
    pub per_pe: f64,
    /// Fixed cost per chiplet (die overhead, packaging, test).
    pub per_chiplet: f64,
    /// Cost per KiB of per-chiplet buffer.
    pub per_buffer_kib: f64,
    /// Extra cost per chiplet for the wireless transceiver pair.
    pub wireless_per_chiplet: f64,
    /// Extra cost per chiplet for interposer wiring + µbumps.
    pub interposer_per_chiplet: f64,
    /// Multiplier applied to aggressive (higher-BW) NoP provisioning.
    pub aggressive_factor: f64,
    /// Fixed per-package cost (substrate, HBM, global SRAM chiplet).
    pub per_package: f64,
}

/// Dollar scale for 65-nm silicon area. One free constant — every other
/// dollar figure below derives from a Table-3 area through it.
pub const DOLLARS_PER_MM2: f64 = 12.0;

/// Wireless RX area per chiplet implied by Table 3 / §6: the RX is 16% of
/// a chiplet (PE array + collection router + RX).
fn rx_area_mm2() -> f64 {
    let chiplet_logic = PE_AREA_MM2 * 64.0 + ROUTER_AREA_MM2;
    (0.16 / 0.84) * chiplet_logic
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            // Eyeriss-derived PE + local memory slice: ~0.078 mm²/PE.
            per_pe: PE_AREA_MM2 * DOLLARS_PER_MM2,
            // Die overhead, packaging, test — not a Table-3 quantity.
            per_chiplet: 40.0,
            // Buffer priced at the Table-3 SRAM area density per KiB.
            per_buffer_kib: SRAM_AREA_MM2_PER_MIB / 1024.0 * DOLLARS_PER_MM2,
            // Transceiver premium: the paper's 16%-of-chiplet RX.
            wireless_per_chiplet: rx_area_mm2() * DOLLARS_PER_MM2,
            // Interposer wiring + µbumps per chiplet — estimate.
            interposer_per_chiplet: 8.0,
            aggressive_factor: 1.5,
            // Memory chiplet (13 MiB global SRAM + TX at ~2x RX area)
            // plus substrate/HBM estimate.
            per_package: (SRAM_AREA_MM2_PER_MIB * 13.0 + 2.0 * rx_area_mm2()) * DOLLARS_PER_MM2
                + 1300.0,
        }
    }
}

impl CostModel {
    /// Cost of one package built at `p`.
    pub fn package_cost(&self, p: &PackagePoint) -> f64 {
        let nop_per_chiplet = match p.dp.nop {
            NopKind::Wireless => self.wireless_per_chiplet,
            NopKind::Interposer => self.interposer_per_chiplet,
        };
        let aggr = match p.dp.aggr {
            crate::config::Aggressiveness::Aggressive => self.aggressive_factor,
            crate::config::Aggressiveness::Conservative => 1.0,
        };
        let per_chiplet = self.per_chiplet
            + self.per_pe * p.pes_per_chiplet as f64
            + self.per_buffer_kib * (p.local_buffer_bytes as f64 / 1024.0)
            + nop_per_chiplet * aggr;
        self.per_package + per_chiplet * p.num_chiplets as f64
    }

    /// Cost of `width` packages at `p`.
    pub fn fleet_cost(&self, p: &PackagePoint, width: u64) -> f64 {
        self.package_cost(p) * width as f64
    }
}

/// The grid of package candidates the auto-sizer enumerates.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub chiplet_counts: Vec<u64>,
    pub pes_per_chiplet: Vec<u64>,
    pub buffer_bytes: Vec<u64>,
    pub design_points: Vec<DesignPoint>,
    /// Largest fleet width the per-candidate bisection may try.
    pub max_width: u64,
}

impl Default for SearchSpace {
    /// 4 × 4 × 4 × 4 = 256 package points around the Table-4 instance.
    fn default() -> Self {
        SearchSpace {
            chiplet_counts: vec![32, 64, 128, 256],
            pes_per_chiplet: vec![16, 32, 64, 128],
            buffer_bytes: vec![128 * 1024, 256 * 1024, 512 * 1024, 1024 * 1024],
            design_points: DesignPoint::ALL.to_vec(),
            max_width: 32,
        }
    }
}

impl SearchSpace {
    /// A deliberately tiny space for tests: 2 × 1 × 1 × 2 = 4 points.
    pub fn tiny() -> Self {
        SearchSpace {
            chiplet_counts: vec![64, 256],
            pes_per_chiplet: vec![64],
            buffer_bytes: vec![512 * 1024],
            design_points: vec![DesignPoint::WIENNA_C, DesignPoint::INTERPOSER_C],
            max_width: 8,
        }
    }

    /// Every package point of the grid, in deterministic order.
    pub fn enumerate(&self) -> Vec<PackagePoint> {
        let mut out = Vec::with_capacity(
            self.design_points.len()
                * self.chiplet_counts.len()
                * self.pes_per_chiplet.len()
                * self.buffer_bytes.len(),
        );
        for &dp in &self.design_points {
            for &num_chiplets in &self.chiplet_counts {
                for &pes_per_chiplet in &self.pes_per_chiplet {
                    for &local_buffer_bytes in &self.buffer_bytes {
                        out.push(PackagePoint { dp, num_chiplets, pes_per_chiplet, local_buffer_bytes });
                    }
                }
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.design_points.len()
            * self.chiplet_counts.len()
            * self.pes_per_chiplet.len()
            * self.buffer_bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_space_has_at_least_256_points() {
        let s = SearchSpace::default();
        assert!(s.len() >= 256, "{} points", s.len());
        assert_eq!(s.enumerate().len(), s.len());
    }

    #[test]
    fn enumeration_is_deterministic_and_unique() {
        let s = SearchSpace::default();
        let a = s.enumerate();
        let b = s.enumerate();
        assert_eq!(a, b);
        let set: std::collections::HashSet<PackagePoint> = a.iter().copied().collect();
        assert_eq!(set.len(), a.len());
    }

    #[test]
    fn cost_grows_with_every_axis() {
        let m = CostModel::default();
        let base = PackagePoint {
            dp: DesignPoint::WIENNA_C,
            num_chiplets: 64,
            pes_per_chiplet: 64,
            local_buffer_bytes: 256 * 1024,
        };
        let c0 = m.package_cost(&base);
        assert!(c0 > 0.0);
        assert!(m.package_cost(&PackagePoint { num_chiplets: 128, ..base }) > c0);
        assert!(m.package_cost(&PackagePoint { pes_per_chiplet: 128, ..base }) > c0);
        assert!(m.package_cost(&PackagePoint { local_buffer_bytes: 1024 * 1024, ..base }) > c0);
        assert!(m.package_cost(&PackagePoint { dp: DesignPoint::WIENNA_A, ..base }) > c0);
        assert!(m.fleet_cost(&base, 3) > m.fleet_cost(&base, 2));
    }

    #[test]
    fn calibrated_constants_track_table3_areas() {
        let m = CostModel::default();
        // Per-PE dollars = Eyeriss PE area x scale (5 mm² / 64 PEs).
        assert!((m.per_pe - (5.0 / 64.0) * DOLLARS_PER_MM2).abs() < 1e-12);
        // Buffer: 13 MiB of SRAM is 51 mm² (Table 3) -> per-KiB dollars.
        assert!((m.per_buffer_kib - 51.0 / 13.0 / 1024.0 * DOLLARS_PER_MM2).abs() < 1e-12);
        // The RX premium lands near the paper's 16%-of-chiplet figure:
        // ~1.03 mm² against the 5.43 mm² PE-array+router chiplet.
        let rx = m.wireless_per_chiplet / DOLLARS_PER_MM2;
        assert!(rx > 0.9 && rx < 1.2, "RX area {rx} mm²");
    }

    #[test]
    fn wienna_package_premium_is_modest() {
        // Regression pin for the paper's "modest area and power
        // overheads": at the Table-4 geometry, the wireless package costs
        // 0-10% more than the same-geometry interposer package — the
        // premium must neither vanish (the transceivers are not free) nor
        // balloon (it would undercut the co-design argument).
        let m = CostModel::default();
        let geom = |dp| PackagePoint {
            dp,
            num_chiplets: 256,
            pes_per_chiplet: 64,
            local_buffer_bytes: 512 * 1024,
        };
        let wienna = m.package_cost(&geom(DesignPoint::WIENNA_C));
        let interposer = m.package_cost(&geom(DesignPoint::INTERPOSER_C));
        let overhead = wienna / interposer - 1.0;
        assert!(overhead > 0.0, "wireless premium vanished ({overhead:.3})");
        assert!(overhead < 0.10, "wireless premium ballooned ({overhead:.3})");
    }

    #[test]
    fn package_point_builds_specs() {
        let p = PackagePoint {
            dp: DesignPoint::WIENNA_C,
            num_chiplets: 64,
            pes_per_chiplet: 32,
            local_buffer_bytes: 256 * 1024,
        };
        let fleet = p.fleet(3);
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[0].sys.num_chiplets, 64);
        assert_eq!(fleet[0].sys.pes_per_chiplet, 32);
        assert_eq!(fleet[2].dp, DesignPoint::WIENNA_C);
        assert_eq!(fleet[1].local_buffer_bytes, 256 * 1024);
    }
}
