//! The auto-sizer's design space: package candidates, their dollar-cost
//! model, and dominance pruning.
//!
//! A *package point* is one buildable package configuration — design
//! point (NoP kind × aggressiveness), chiplet count, PEs per chiplet and
//! per-chiplet buffer budget. The fleet dimension (how many packages sit
//! behind the router) is searched separately per candidate
//! (`search::autosize`), because feasibility at a load is a property of
//! the whole fleet.

use crate::config::{DesignPoint, SystemConfig};
use crate::nop::NopKind;
use crate::serve::PackageSpec;

/// One candidate package configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PackagePoint {
    pub dp: DesignPoint,
    pub num_chiplets: u64,
    pub pes_per_chiplet: u64,
    /// Per-chiplet double-buffer budget for inter-layer pipelining.
    pub local_buffer_bytes: u64,
}

impl PackagePoint {
    /// The package's system configuration (Table-4 defaults for the axes
    /// the search does not vary).
    pub fn sys(&self) -> SystemConfig {
        SystemConfig {
            num_chiplets: self.num_chiplets,
            pes_per_chiplet: self.pes_per_chiplet,
            ..Default::default()
        }
    }

    /// Instantiate this point as a named [`PackageSpec`].
    pub fn spec(&self, name: &str) -> PackageSpec {
        PackageSpec::custom(name, self.sys(), self.dp, self.local_buffer_bytes)
    }

    /// `width` identical packages of this point.
    pub fn fleet(&self, width: u64) -> Vec<PackageSpec> {
        (0..width).map(|i| self.spec(&format!("{}-{i}", self.label()))).collect()
    }

    pub fn label(&self) -> String {
        format!(
            "{}/{}c x {}pe/{}KiB",
            self.dp.label(),
            self.num_chiplets,
            self.pes_per_chiplet,
            self.local_buffer_bytes / 1024
        )
    }
}

/// Relative dollar cost of building packages. Absolute calibration is
/// irrelevant to the search — only ratios steer it — so the defaults are
/// round numbers: silicon scales with PE count, per-chiplet overhead
/// covers packaging/test, SRAM-backed buffers are priced per KiB, and
/// wireless packages pay a transceiver premium per chiplet but skip the
/// interposer's per-link wiring cost.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Cost per PE (compute silicon).
    pub per_pe: f64,
    /// Fixed cost per chiplet (die overhead, packaging, test).
    pub per_chiplet: f64,
    /// Cost per KiB of per-chiplet buffer.
    pub per_buffer_kib: f64,
    /// Extra cost per chiplet for the wireless transceiver pair.
    pub wireless_per_chiplet: f64,
    /// Extra cost per chiplet for interposer wiring + µbumps.
    pub interposer_per_chiplet: f64,
    /// Multiplier applied to aggressive (higher-BW) NoP provisioning.
    pub aggressive_factor: f64,
    /// Fixed per-package cost (substrate, HBM, global SRAM chiplet).
    pub per_package: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            per_pe: 1.0,
            per_chiplet: 40.0,
            per_buffer_kib: 0.05,
            wireless_per_chiplet: 12.0,
            interposer_per_chiplet: 8.0,
            aggressive_factor: 1.5,
            per_package: 2000.0,
        }
    }
}

impl CostModel {
    /// Cost of one package built at `p`.
    pub fn package_cost(&self, p: &PackagePoint) -> f64 {
        let nop_per_chiplet = match p.dp.nop {
            NopKind::Wireless => self.wireless_per_chiplet,
            NopKind::Interposer => self.interposer_per_chiplet,
        };
        let aggr = match p.dp.aggr {
            crate::config::Aggressiveness::Aggressive => self.aggressive_factor,
            crate::config::Aggressiveness::Conservative => 1.0,
        };
        let per_chiplet = self.per_chiplet
            + self.per_pe * p.pes_per_chiplet as f64
            + self.per_buffer_kib * (p.local_buffer_bytes as f64 / 1024.0)
            + nop_per_chiplet * aggr;
        self.per_package + per_chiplet * p.num_chiplets as f64
    }

    /// Cost of `width` packages at `p`.
    pub fn fleet_cost(&self, p: &PackagePoint, width: u64) -> f64 {
        self.package_cost(p) * width as f64
    }
}

/// The grid of package candidates the auto-sizer enumerates.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub chiplet_counts: Vec<u64>,
    pub pes_per_chiplet: Vec<u64>,
    pub buffer_bytes: Vec<u64>,
    pub design_points: Vec<DesignPoint>,
    /// Largest fleet width the per-candidate bisection may try.
    pub max_width: u64,
}

impl Default for SearchSpace {
    /// 4 × 4 × 4 × 4 = 256 package points around the Table-4 instance.
    fn default() -> Self {
        SearchSpace {
            chiplet_counts: vec![32, 64, 128, 256],
            pes_per_chiplet: vec![16, 32, 64, 128],
            buffer_bytes: vec![128 * 1024, 256 * 1024, 512 * 1024, 1024 * 1024],
            design_points: DesignPoint::ALL.to_vec(),
            max_width: 32,
        }
    }
}

impl SearchSpace {
    /// A deliberately tiny space for tests: 2 × 1 × 1 × 2 = 4 points.
    pub fn tiny() -> Self {
        SearchSpace {
            chiplet_counts: vec![64, 256],
            pes_per_chiplet: vec![64],
            buffer_bytes: vec![512 * 1024],
            design_points: vec![DesignPoint::WIENNA_C, DesignPoint::INTERPOSER_C],
            max_width: 8,
        }
    }

    /// Every package point of the grid, in deterministic order.
    pub fn enumerate(&self) -> Vec<PackagePoint> {
        let mut out = Vec::with_capacity(
            self.design_points.len()
                * self.chiplet_counts.len()
                * self.pes_per_chiplet.len()
                * self.buffer_bytes.len(),
        );
        for &dp in &self.design_points {
            for &num_chiplets in &self.chiplet_counts {
                for &pes_per_chiplet in &self.pes_per_chiplet {
                    for &local_buffer_bytes in &self.buffer_bytes {
                        out.push(PackagePoint { dp, num_chiplets, pes_per_chiplet, local_buffer_bytes });
                    }
                }
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.design_points.len()
            * self.chiplet_counts.len()
            * self.pes_per_chiplet.len()
            * self.buffer_bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_space_has_at_least_256_points() {
        let s = SearchSpace::default();
        assert!(s.len() >= 256, "{} points", s.len());
        assert_eq!(s.enumerate().len(), s.len());
    }

    #[test]
    fn enumeration_is_deterministic_and_unique() {
        let s = SearchSpace::default();
        let a = s.enumerate();
        let b = s.enumerate();
        assert_eq!(a, b);
        let set: std::collections::HashSet<PackagePoint> = a.iter().copied().collect();
        assert_eq!(set.len(), a.len());
    }

    #[test]
    fn cost_grows_with_every_axis() {
        let m = CostModel::default();
        let base = PackagePoint {
            dp: DesignPoint::WIENNA_C,
            num_chiplets: 64,
            pes_per_chiplet: 64,
            local_buffer_bytes: 256 * 1024,
        };
        let c0 = m.package_cost(&base);
        assert!(c0 > 0.0);
        assert!(m.package_cost(&PackagePoint { num_chiplets: 128, ..base }) > c0);
        assert!(m.package_cost(&PackagePoint { pes_per_chiplet: 128, ..base }) > c0);
        assert!(m.package_cost(&PackagePoint { local_buffer_bytes: 1024 * 1024, ..base }) > c0);
        assert!(m.package_cost(&PackagePoint { dp: DesignPoint::WIENNA_A, ..base }) > c0);
        assert!(m.fleet_cost(&base, 3) > m.fleet_cost(&base, 2));
    }

    #[test]
    fn package_point_builds_specs() {
        let p = PackagePoint {
            dp: DesignPoint::WIENNA_C,
            num_chiplets: 64,
            pes_per_chiplet: 32,
            local_buffer_bytes: 256 * 1024,
        };
        let fleet = p.fleet(3);
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[0].sys.num_chiplets, 64);
        assert_eq!(fleet[0].sys.pes_per_chiplet, 32);
        assert_eq!(fleet[2].dp, DesignPoint::WIENNA_C);
        assert_eq!(fleet[1].local_buffer_bytes, 256 * 1024);
    }
}
