//! Energy and area models (substrate S9): the Table-3 component library
//! and the Fig-9 distribution-energy aggregation.

pub mod area;
pub mod distribution;
pub mod system;

pub use area::{AreaPowerBreakdown, ComponentBudget};
pub use distribution::{model_distribution_energy, EnergyComparison};
pub use system::{system_energy, EnergyConstants, SystemEnergy, TrafficTotals};
