//! WIENNA area/power breakdown (paper Table 3, substrate S9).
//!
//! Component constants follow the paper's sources: PE array and SRAM
//! numbers are Eyeriss-derived [6] at 65-nm CMOS; the wireless TX/RX are
//! produced by the Fig-1 transceiver fit at the design bandwidth and
//! 1e-9 BER; the collection-NoP router is a Simba-class mesh router.

use crate::config::{SystemConfig, CLOCK_HZ};
use crate::nop::transceiver::{required_gbps, Transceiver};

/// Eyeriss-derived per-PE constants at 65 nm (PE + its slice of local
/// memory). Chosen so that 64 PEs + local memory ≈ 5 mm² / 90 mW as in
/// Table 3.
pub const PE_AREA_MM2: f64 = 5.0 / 64.0;
pub const PE_POWER_MW: f64 = 90.0 / 64.0;

/// Global SRAM at 65 nm: 51 mm² and 10 W for 13 MiB (Table 3).
pub const SRAM_AREA_MM2_PER_MIB: f64 = 51.0 / 13.0;
pub const SRAM_POWER_MW_PER_MIB: f64 = 10000.0 / 13.0;

/// Collection-NoP router per chiplet (Table 3).
pub const ROUTER_AREA_MM2: f64 = 0.43;
pub const ROUTER_POWER_MW: f64 = 170.0;

/// One component row of the breakdown.
#[derive(Debug, Clone)]
pub struct ComponentBudget {
    pub name: String,
    pub area_mm2: f64,
    pub power_mw: f64,
    /// Number of instances aggregated into this row.
    pub count: u64,
}

/// Full Table-3-style breakdown.
#[derive(Debug, Clone)]
pub struct AreaPowerBreakdown {
    pub components: Vec<ComponentBudget>,
}

impl AreaPowerBreakdown {
    /// Build the breakdown for a system configuration with the given
    /// wireless distribution bandwidth (bytes/cycle). RX datarate equals
    /// the air rate; the single TX must sustain the same rate.
    pub fn for_system(sys: &SystemConfig, wireless_bw_bytes_per_cycle: f64, ber: f64) -> Self {
        let trx = Transceiver::default();
        let gbps = required_gbps(wireless_bw_bytes_per_cycle, CLOCK_HZ);
        // An RX is roughly half a transceiver; the TX needs more gain
        // (it drives the whole package) — Table 3 charges it 2x the RX
        // area and ~2x power.
        let rx_area = trx.area_mm2(gbps) * 0.55;
        let rx_power = trx.power_mw(gbps, ber) * 0.5;
        let tx_area = rx_area * 2.0;
        let tx_power = rx_power * 1.85;

        let nc = sys.num_chiplets;
        let pes = sys.pes_per_chiplet;
        let sram_mib = sys.global_sram_bytes as f64 / (1024.0 * 1024.0);

        AreaPowerBreakdown {
            components: vec![
                ComponentBudget {
                    name: format!("PEs ({pes}x) + Mem"),
                    area_mm2: PE_AREA_MM2 * pes as f64 * nc as f64,
                    power_mw: PE_POWER_MW * pes as f64 * nc as f64,
                    count: nc,
                },
                ComponentBudget {
                    name: "Wireless RX".into(),
                    area_mm2: rx_area * nc as f64,
                    power_mw: rx_power * nc as f64,
                    count: nc,
                },
                ComponentBudget {
                    name: "Collection NoP Router".into(),
                    area_mm2: ROUTER_AREA_MM2 * nc as f64,
                    power_mw: ROUTER_POWER_MW * nc as f64,
                    count: nc,
                },
                ComponentBudget {
                    name: "Global SRAM".into(),
                    area_mm2: SRAM_AREA_MM2_PER_MIB * sram_mib,
                    power_mw: SRAM_POWER_MW_PER_MIB * sram_mib,
                    count: 1,
                },
                ComponentBudget { name: "Wireless TX".into(), area_mm2: tx_area, power_mw: tx_power, count: 1 },
            ],
        }
    }

    pub fn total_area_mm2(&self) -> f64 {
        self.components.iter().map(|c| c.area_mm2).sum()
    }

    pub fn total_power_mw(&self) -> f64 {
        self.components.iter().map(|c| c.power_mw).sum()
    }

    fn find(&self, name: &str) -> &ComponentBudget {
        self.components.iter().find(|c| c.name.contains(name)).unwrap()
    }

    /// Wireless RX share of one chiplet's area (paper: 16%).
    pub fn rx_area_fraction_of_chiplet(&self) -> f64 {
        let rx = self.find("Wireless RX");
        let pe = self.find("PEs");
        let router = self.find("Router");
        rx.area_mm2 / (rx.area_mm2 + pe.area_mm2 + router.area_mm2)
    }

    /// Wireless RX share of one chiplet's power (paper: 25%).
    pub fn rx_power_fraction_of_chiplet(&self) -> f64 {
        let rx = self.find("Wireless RX");
        let pe = self.find("PEs");
        let router = self.find("Router");
        rx.power_mw / (rx.power_mw + pe.power_mw + router.power_mw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_near_table3() {
        let b = AreaPowerBreakdown::for_system(&SystemConfig::default(), 16.0, 1e-9);
        // Table 3: total 1699 mm², 99.8 W. Allow modest slack — the
        // TRX sub-model is a fit, not a lookup.
        let area = b.total_area_mm2();
        let power = b.total_power_mw();
        assert!(area > 1400.0 && area < 2000.0, "area {area}");
        assert!(power > 80_000.0 && power < 120_000.0, "power {power}");
    }

    #[test]
    fn rx_fractions_near_paper() {
        let b = AreaPowerBreakdown::for_system(&SystemConfig::default(), 16.0, 1e-9);
        let fa = b.rx_area_fraction_of_chiplet();
        let fp = b.rx_power_fraction_of_chiplet();
        assert!(fa > 0.05 && fa < 0.30, "area fraction {fa}");
        assert!(fp > 0.10 && fp < 0.40, "power fraction {fp}");
    }

    #[test]
    fn wireless_overhead_vs_interposer_baseline_is_modest() {
        // Regression pin for the paper's "modest area and power
        // overheads" claim: the wireless machinery (all RX rows + the TX)
        // on top of an interposer-style baseline (PEs + routers + SRAM)
        // must stay a minority share of the package — the §6 numbers put
        // the RX at 16% of chiplet area / 25% of chiplet power, which
        // dilutes further at package level once the SRAM chiplet counts.
        let b = AreaPowerBreakdown::for_system(&SystemConfig::default(), 16.0, 1e-9);
        let wireless_area: f64 = b
            .components
            .iter()
            .filter(|c| c.name.contains("Wireless"))
            .map(|c| c.area_mm2)
            .sum();
        let wireless_power: f64 = b
            .components
            .iter()
            .filter(|c| c.name.contains("Wireless"))
            .map(|c| c.power_mw)
            .sum();
        let area_overhead = wireless_area / (b.total_area_mm2() - wireless_area);
        let power_overhead = wireless_power / (b.total_power_mw() - wireless_power);
        assert!(
            area_overhead > 0.03 && area_overhead < 0.30,
            "area overhead {:.1}% out of the modest band",
            area_overhead * 100.0
        );
        assert!(
            power_overhead > 0.05 && power_overhead < 0.40,
            "power overhead {:.1}% out of the modest band",
            power_overhead * 100.0
        );
    }

    #[test]
    fn sram_dominates_memory_chiplet() {
        let b = AreaPowerBreakdown::for_system(&SystemConfig::default(), 16.0, 1e-9);
        let sram = b.components.iter().find(|c| c.name == "Global SRAM").unwrap();
        let tx = b.components.iter().find(|c| c.name == "Wireless TX").unwrap();
        assert!(sram.area_mm2 > 10.0 * tx.area_mm2);
    }
}
