//! Fig-9 distribution-energy aggregation: interposer vs WIENNA energy for
//! the distribution of input activations and filters, per layer and per
//! strategy, plus the end-to-end reduction summary (Fig 9c).

use crate::config::{DesignPoint, SystemConfig};
use crate::cost::{evaluate_model, CostEngine};
use crate::dataflow::Strategy;
use crate::workload::Model;

/// Energy of one (model, strategy) pair on both fabrics.
#[derive(Debug, Clone)]
pub struct EnergyComparison {
    pub model_name: String,
    pub strategy: Option<Strategy>,
    /// Interposer distribution energy in pJ.
    pub interposer_pj: f64,
    /// WIENNA distribution energy in pJ.
    pub wienna_pj: f64,
}

impl EnergyComparison {
    /// Fractional reduction achieved by WIENNA (paper avg: 38.2%).
    pub fn reduction(&self) -> f64 {
        1.0 - self.wienna_pj / self.interposer_pj
    }
}

/// Compare distribution energy between the interposer baseline and WIENNA
/// for a model under a fixed (or adaptive, `None`) strategy. Conservative
/// design points are used for both, as in Fig 9.
///
/// Fig 9 compares the energy of moving the *same* tensors: under the
/// adaptive policy the per-layer strategies are selected once (on the
/// WIENNA engine, whose reconfigurable NoP enables per-layer switching,
/// §4) and the identical strategy sequence is charged on both fabrics.
pub fn model_distribution_energy(sys: &SystemConfig, model: &Model, strategy: Option<Strategy>) -> EnergyComparison {
    let ei = CostEngine::for_design_point(sys, DesignPoint::INTERPOSER_C);
    let ew = CostEngine::for_design_point(sys, DesignPoint::WIENNA_C);
    let (interposer_pj, wienna_pj) = match strategy {
        Some(_) => (
            evaluate_model(&ei, model, strategy).total_dist_energy_pj,
            evaluate_model(&ew, model, strategy).total_dist_energy_pj,
        ),
        None => {
            let mut ipj = 0.0;
            let mut wpj = 0.0;
            for layer in &model.layers {
                let (s, wcost) = crate::cost::best_strategy(&ew, layer);
                wpj += wcost.dist_energy_pj;
                ipj += crate::cost::evaluate_layer(&ei, layer, s).dist_energy_pj;
            }
            (ipj, wpj)
        }
    };
    EnergyComparison { model_name: model.name.clone(), strategy, interposer_pj, wienna_pj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet50, unet};

    #[test]
    fn wienna_reduces_energy_on_both_networks() {
        let sys = SystemConfig::default();
        for model in [resnet50::resnet50(16), unet::unet(4)] {
            for strat in [None, Some(Strategy::KpCp), Some(Strategy::NpCp), Some(Strategy::YpXp)] {
                let cmp = model_distribution_energy(&sys, &model, strat);
                assert!(
                    cmp.reduction() > 0.0,
                    "{} {:?}: reduction {:.1}%",
                    cmp.model_name,
                    strat,
                    cmp.reduction() * 100.0
                );
            }
        }
    }

    #[test]
    fn reduction_in_papers_ballpark() {
        // Paper Fig 9c: average 38.2% end-to-end reduction. Accept a wide
        // band — our substrate is a reimplementation, not the authors'.
        let sys = SystemConfig::default();
        let cmp = model_distribution_energy(&sys, &resnet50::resnet50(16), None);
        let r = cmp.reduction();
        assert!(r > 0.15 && r < 0.95, "reduction {:.1}%", r * 100.0);
    }
}
