//! Whole-system energy: compute + memory + interconnect for one
//! inference, and the resulting efficiency (TOPS/W-class) figures.
//!
//! Fig 9 isolates *distribution* energy (where WIENNA differs from the
//! baseline); this module adds the strategy-invariant components — PE
//! switching energy, global-SRAM accesses, HBM traffic and collection —
//! so users can see the technique's impact in whole-inference terms.
//! Constants are Eyeriss-derived 65-nm figures, consistent with Table 3.

use crate::config::CLOCK_HZ;
use crate::cost::{LayerCost, ModelCost};

/// Energy constants at 65 nm (pJ).
#[derive(Debug, Clone)]
pub struct EnergyConstants {
    /// One 8-bit MAC operation.
    pub mac_pj: f64,
    /// One byte read/written at the global SRAM.
    pub sram_byte_pj: f64,
    /// One byte moved over the collection mesh per hop.
    pub collect_byte_hop_pj: f64,
    /// Idle/leakage power of the full package in mW (burned over the
    /// run's latency).
    pub idle_mw: f64,
}

impl Default for EnergyConstants {
    fn default() -> Self {
        EnergyConstants {
            mac_pj: 0.5,              // Eyeriss-class 16-bit MAC ≈ 1 pJ; int8 ≈ 0.5
            sram_byte_pj: 8.0,        // large-SRAM access, per byte
            collect_byte_hop_pj: 0.82 * 8.0,
            idle_mw: 5000.0,          // ~5% of the Table-3 power budget
        }
    }
}

/// Traffic aggregates that drive dynamic energy — THE single definition
/// shared by the static whole-system path ([`system_energy`]) and the
/// runtime meter (`serve::CostCache` fills them into `BatchCost`;
/// `power::PowerModel` prices them per batch), so the two can never
/// desynchronize.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TrafficTotals {
    pub macs: f64,
    /// Global-SRAM bytes: the SRAM reads every distributed byte and
    /// writes every collected byte.
    pub sram_bytes: f64,
    /// Distribution energy in pJ, straight from the NoP models (Fig 9).
    pub dist_energy_pj: f64,
    /// Collected bytes × average mesh hops (collection-NoP traffic).
    pub collect_byte_hops: f64,
}

impl TrafficTotals {
    /// Aggregate per-layer costs. `avg_hops` is the collection mesh's
    /// average hop count (√N_C/2).
    pub fn from_layers(layers: &[LayerCost], avg_hops: f64) -> Self {
        let mut t = TrafficTotals::default();
        for l in layers {
            t.macs += l.macs as f64;
            t.sram_bytes += (l.dist_bytes + l.collect_bytes) as f64;
            t.dist_energy_pj += l.dist_energy_pj;
            t.collect_byte_hops += l.collect_bytes as f64 * avg_hops;
        }
        t
    }

    /// Price the aggregates at `k`, in mJ:
    /// `[compute, sram, distribution, collection]`.
    pub fn price_mj(&self, k: &EnergyConstants) -> [f64; 4] {
        [
            self.macs * k.mac_pj * 1e-9,
            self.sram_bytes * k.sram_byte_pj * 1e-9,
            self.dist_energy_pj * 1e-9,
            self.collect_byte_hops * k.collect_byte_hop_pj * 1e-9,
        ]
    }
}

/// Whole-run energy breakdown in millijoules.
#[derive(Debug, Clone, PartialEq)]
pub struct SystemEnergy {
    pub compute_mj: f64,
    pub sram_mj: f64,
    pub distribution_mj: f64,
    pub collection_mj: f64,
    pub idle_mj: f64,
}

impl SystemEnergy {
    pub fn total_mj(&self) -> f64 {
        self.compute_mj + self.sram_mj + self.distribution_mj + self.collection_mj + self.idle_mj
    }

    /// Effective efficiency in GMAC/s per watt ( = TOPS/W at 2 ops/MAC /
    /// 1000) for a run of `total_macs` in `latency_cycles`.
    pub fn gmacs_per_watt(&self, total_macs: u64, latency_cycles: f64) -> f64 {
        let seconds = latency_cycles / CLOCK_HZ;
        let watts = self.total_mj() * 1e-3 / seconds;
        (total_macs as f64 / seconds) / 1e9 / watts
    }
}

/// Aggregate a [`ModelCost`] into a whole-system energy estimate.
///
/// `avg_hops` is the collection mesh's average hop count (√N_C/2).
pub fn system_energy(cost: &ModelCost, avg_hops: f64, k: &EnergyConstants) -> SystemEnergy {
    let t = TrafficTotals::from_layers(&cost.layers, avg_hops);
    let [compute_mj, sram_mj, distribution_mj, collection_mj] = t.price_mj(k);
    SystemEnergy {
        compute_mj,
        sram_mj,
        distribution_mj,
        collection_mj,
        idle_mj: k.idle_mw * (cost.total_latency / CLOCK_HZ) * 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignPoint, SystemConfig};
    use crate::cost::{evaluate_model, CostEngine};
    use crate::workload::resnet50::resnet50;

    fn run(dp: DesignPoint) -> (ModelCost, SystemEnergy) {
        let sys = SystemConfig::default();
        let e = CostEngine::for_design_point(&sys, dp);
        let cost = evaluate_model(&e, &resnet50(16), None);
        let se = system_energy(&cost, sys.avg_mesh_hops(), &EnergyConstants::default());
        (cost, se)
    }

    #[test]
    fn all_components_positive() {
        let (_, se) = run(DesignPoint::WIENNA_C);
        assert!(se.compute_mj > 0.0 && se.sram_mj > 0.0);
        assert!(se.distribution_mj > 0.0 && se.collection_mj > 0.0);
        assert!(se.idle_mj > 0.0);
    }

    #[test]
    fn wienna_wins_whole_system_energy() {
        // Faster run = less idle burn, cheaper distribution: the whole-
        // system comparison must still favor WIENNA (weaker than the
        // Fig-9 distribution-only ratio, but positive).
        let (_, wi) = run(DesignPoint::WIENNA_C);
        let (_, ip) = run(DesignPoint::INTERPOSER_C);
        assert!(wi.total_mj() < ip.total_mj(), "WIENNA {} vs interposer {}", wi.total_mj(), ip.total_mj());
    }

    #[test]
    fn efficiency_is_sane() {
        // 16K MACs at 500 MHz peak = 8.2 TMAC/s; with a ~100 W budget the
        // efficiency must land between 0.01 and 1 TMAC/s/W.
        let (cost, se) = run(DesignPoint::WIENNA_A);
        let eff = se.gmacs_per_watt(cost.total_macs, cost.total_latency);
        assert!(eff > 10.0 && eff < 1000.0, "{eff} GMAC/s/W");
    }

    #[test]
    fn compute_energy_is_strategy_invariant() {
        let (a, ea) = run(DesignPoint::WIENNA_C);
        let (b, eb) = run(DesignPoint::INTERPOSER_A);
        assert_eq!(a.total_macs, b.total_macs);
        assert!((ea.compute_mj - eb.compute_mj).abs() < 1e-9);
    }
}
