"""AOT path tests: HLO text is produced, parseable, and manifest-complete."""

import os
import tempfile

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    lines = aot.build_artifacts(out, perf_tiles=())
    return out, lines


class TestAotBuild:
    def test_manifest_written(self, built):
        out, lines = built
        assert os.path.exists(os.path.join(out, "manifest.txt"))
        assert any(l.startswith("version ") for l in lines)

    def test_all_artifact_files_exist(self, built):
        out, lines = built
        for line in lines:
            if line.startswith("artifact "):
                fname = line.split()[2]
                assert os.path.exists(os.path.join(out, fname)), fname

    def test_hlo_is_text_not_proto(self, built):
        out, lines = built
        for line in lines:
            if line.startswith("artifact "):
                path = os.path.join(out, line.split()[2])
                with open(path) as f:
                    head = f.read(200)
                # HLO text modules start with "HloModule".
                assert head.lstrip().startswith("HloModule"), head[:50]

    def test_matmul_hlo_declares_tuple_root(self, built):
        out, _ = built
        with open(os.path.join(out, f"matmul{aot.TILE}.hlo.txt")) as f:
            text = f.read()
        # return_tuple=True => root computation returns a tuple of one f32
        # tensor of the tile shape.
        assert f"(f32[{aot.TILE},{aot.TILE}]" in text

    def test_manifest_shapes_match_contract(self, built):
        _, lines = built
        arts = {l.split()[1]: l.split() for l in lines
                if l.startswith("artifact ")}
        m = arts[f"matmul{aot.TILE}"]
        assert m[4] == f"{aot.TILE}x{aot.TILE};{aot.TILE}x{aot.TILE}"
        assert m[5] == f"{aot.TILE}x{aot.TILE}"
        a = arts[f"add{aot.ADD_CHUNK}"]
        assert a[4] == f"{aot.ADD_CHUNK};{aot.ADD_CHUNK}"


def test_hlo_text_reparses_via_xla_client(built=None):
    """Round-trip: the emitted text parses back into an XlaComputation —
    the same entry point the Rust xla crate uses."""
    import jax
    import jax.numpy as jnp
    from compile import model
    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    text = aot.to_hlo_text(jax.jit(model.chiplet_matmul).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[64,64]" in text
