"""L1 correctness: output-stationary 3x3 conv kernel vs lax reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.conv_os import conv3x3_os, vmem_footprint_bytes
from compile.kernels.ref import conv2d_nchw_ref

RTOL = 1e-3
ATOL = 1e-3


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape),
                       jnp.float32)


def run_os(x, w, kt):
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1)))
    return conv3x3_os(xp, w, kt=kt)


class TestConvOsBasic:
    def test_matches_lax_same_conv(self):
        x = rand((16, 16, 16), 0)
        w = rand((32, 16, 3, 3), 1)
        out = run_os(x, w, kt=8)
        ref = conv2d_nchw_ref(x[None], w)[0]
        np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)

    def test_single_channel_tile(self):
        x = rand((4, 8, 8), 2)
        w = rand((3, 4, 3, 3), 3)  # K=3 not divisible by 8 -> kt=1
        out = run_os(x, w, kt=1)
        ref = conv2d_nchw_ref(x[None], w)[0]
        np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)

    def test_identity_filter_passthrough(self):
        # Filter that picks the center tap of channel 0.
        x = rand((2, 10, 10), 4)
        w = np.zeros((1, 2, 3, 3), np.float32)
        w[0, 0, 1, 1] = 1.0
        out = run_os(x, jnp.asarray(w), kt=1)
        np.testing.assert_allclose(out[0], x[0], rtol=1e-6, atol=1e-6)

    def test_non_square_rejected(self):
        x = rand((4, 8, 8), 5)
        w = rand((8, 4, 3, 3), 6)
        with pytest.raises(AssertionError):
            conv3x3_os(jnp.pad(x, ((0, 0), (1, 1), (1, 1))), w, kt=3)


@settings(max_examples=15, deadline=None)
@given(
    c=st.sampled_from([1, 4, 16]),
    k=st.sampled_from([8, 16, 32]),
    y=st.sampled_from([8, 16, 32]),
    kt=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_os_property_sweep(c, k, y, kt, seed):
    if k % kt != 0:
        return
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(c, y, y), jnp.float32)
    w = jnp.asarray(rs.randn(k, c, 3, 3), jnp.float32)
    out = run_os(x, w, kt=kt)
    ref = conv2d_nchw_ref(x[None], w)[0]
    np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


class TestChipletConv3x3Entrypoint:
    def test_artifact_entrypoint_matches_ref(self):
        x = rand((16, 32, 32), 7)
        w = rand((32, 16, 3, 3), 8)
        (out,) = model.chiplet_conv3x3(x, w)
        ref = conv2d_nchw_ref(x[None], w)[0]
        np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)

    def test_kt_fallback_for_odd_k(self):
        x = rand((4, 8, 8), 9)
        w = rand((5, 4, 3, 3), 10)  # K=5 -> kt=1
        (out,) = model.chiplet_conv3x3(x, w)
        ref = conv2d_nchw_ref(x[None], w)[0]
        np.testing.assert_allclose(out, ref, rtol=RTOL, atol=ATOL)


class TestVmemFootprint:
    def test_tiny_net_shapes_fit_vmem(self):
        for (c, k, y) in [(16, 32, 32), (32, 32, 32), (64, 64, 16)]:
            kt = 8
            assert vmem_footprint_bytes(c, y, y, kt) < 16 * 2**20

    def test_footprint_grows_with_plane(self):
        assert vmem_footprint_bytes(16, 64, 64, 8) > \
            vmem_footprint_bytes(16, 16, 16, 8)
