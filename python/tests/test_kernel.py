"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/values; every property asserts allclose against
``ref.py``. This is the core correctness signal of the compile path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul_ws import (add_stream, matmul_ws,
                                       mxu_utilization_estimate,
                                       vmem_footprint_bytes)
from compile.kernels.ref import add_ref, matmul_ref

RTOL = 1e-4
ATOL = 1e-4


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape),
                       jnp.float32)


class TestMatmulBasic:
    def test_single_tile(self):
        a, b = rand((64, 64), 0), rand((64, 64), 1)
        np.testing.assert_allclose(matmul_ws(a, b), matmul_ref(a, b),
                                   rtol=RTOL, atol=ATOL)

    def test_multi_tile_all_dims(self):
        a, b = rand((128, 192), 2), rand((192, 256), 3)
        np.testing.assert_allclose(matmul_ws(a, b), matmul_ref(a, b),
                                   rtol=RTOL, atol=ATOL)

    def test_non_square_blocks(self):
        a, b = rand((64, 128), 4), rand((128, 32), 5)
        out = matmul_ws(a, b, bm=32, bk=64, bn=32)
        np.testing.assert_allclose(out, matmul_ref(a, b), rtol=RTOL,
                                   atol=ATOL)

    def test_identity(self):
        eye = jnp.eye(64, dtype=jnp.float32)
        a = rand((64, 64), 6)
        np.testing.assert_allclose(matmul_ws(a, eye), a, rtol=RTOL,
                                   atol=ATOL)

    def test_zeros(self):
        z = jnp.zeros((64, 64), jnp.float32)
        a = rand((64, 64), 7)
        np.testing.assert_allclose(matmul_ws(a, z),
                                   jnp.zeros((64, 64)), atol=1e-6)

    def test_shape_mismatch_rejected(self):
        a, b = rand((64, 64), 8), rand((128, 64), 9)
        with pytest.raises(AssertionError):
            matmul_ws(a, b)

    def test_non_multiple_shape_rejected(self):
        a, b = rand((65, 64), 8), rand((64, 64), 9)
        with pytest.raises(AssertionError):
            matmul_ws(a, b)


@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 3), kt=st.integers(1, 3), nt=st.integers(1, 3),
    block=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_property_sweep(mt, kt, nt, block, seed):
    """Kernel == oracle for every (grid, block) combination."""
    rs = np.random.RandomState(seed)
    a = jnp.asarray(rs.randn(mt * block, kt * block), jnp.float32)
    b = jnp.asarray(rs.randn(kt * block, nt * block), jnp.float32)
    out = matmul_ws(a, b, bm=block, bk=block, bn=block)
    np.testing.assert_allclose(out, matmul_ref(a, b), rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    chunks=st.integers(1, 4),
    block=st.sampled_from([256, 1024, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_add_property_sweep(chunks, block, seed):
    rs = np.random.RandomState(seed)
    n = chunks * block
    a = jnp.asarray(rs.randn(n), jnp.float32)
    b = jnp.asarray(rs.randn(n), jnp.float32)
    out = add_stream(a, b, block=block)
    np.testing.assert_allclose(out, add_ref(a, b), rtol=RTOL, atol=ATOL)


class TestValueEdgeCases:
    @pytest.mark.parametrize("scale", [1e-20, 1e6, -1e6])
    def test_extreme_magnitudes(self, scale):
        a = rand((64, 64), 10) * scale
        b = rand((64, 64), 11)
        np.testing.assert_allclose(matmul_ws(a, b), matmul_ref(a, b),
                                   rtol=1e-3, atol=1e-3 * abs(scale))

    def test_inf_propagates(self):
        a = jnp.full((64, 64), jnp.inf, jnp.float32)
        b = jnp.ones((64, 64), jnp.float32)
        assert bool(jnp.all(jnp.isinf(matmul_ws(a, b))))


class TestRooflineEstimates:
    def test_vmem_footprint_fits_16mib_at_128(self):
        assert vmem_footprint_bytes(128, 128, 128) < 16 * 2**20

    def test_vmem_footprint_formula(self):
        # 2*(bm*bk + bk*bn)*4 + bm*bn*4 + bm*bn*4
        assert vmem_footprint_bytes(64, 64, 64) == (2 * 2 * 64 * 64 * 4
                                                    + 2 * 64 * 64 * 4)

    def test_mxu_full_at_multiples_of_128(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(256, 128, 128) == 1.0

    def test_mxu_partial_below_128(self):
        u = mxu_utilization_estimate(64, 64, 64)
        assert abs(u - 0.125) < 1e-9  # (1/2)^3
