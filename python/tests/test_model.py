"""L2 correctness: the chiplet compute graph vs lax references."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import conv2d_nchw_ref, im2col_matmul_conv_ref


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape),
                       jnp.float32)


class TestChipletConv:
    def test_same_conv_3x3(self):
        x = rand((1, 8, 16, 16), 0)
        w = rand((4, 8, 3, 3), 1)
        out = model.chiplet_conv2d(x, w)
        ref = conv2d_nchw_ref(x, w)
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)

    def test_strided_conv(self):
        x = rand((2, 4, 16, 16), 2)
        w = rand((8, 4, 3, 3), 3)
        out = model.chiplet_conv2d(x, w, stride=2)
        ref = conv2d_nchw_ref(x, w, stride=2)
        assert out.shape == (2, 8, 8, 8)
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)

    def test_1x1_conv_is_channel_mix(self):
        x = rand((1, 16, 8, 8), 4)
        w = rand((32, 16, 1, 1), 5)
        out = model.chiplet_conv2d(x, w)
        ref = conv2d_nchw_ref(x, w)
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)

    def test_im2col_ref_matches_lax(self):
        x = rand((1, 3, 12, 12), 6)
        w = rand((5, 3, 3, 3), 7)
        np.testing.assert_allclose(im2col_matmul_conv_ref(x, w),
                                   conv2d_nchw_ref(x, w),
                                   rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 2), c=st.sampled_from([1, 3, 8]),
    k=st.sampled_from([1, 4, 16]), hw=st.sampled_from([8, 12]),
    rs=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_property_sweep(n, c, k, hw, rs, stride, seed):
    rst = np.random.RandomState(seed)
    x = jnp.asarray(rst.randn(n, c, hw, hw), jnp.float32)
    w = jnp.asarray(rst.randn(k, c, rs, rs), jnp.float32)
    out = model.chiplet_conv2d(x, w, stride=stride)
    ref = conv2d_nchw_ref(x, w, stride=stride)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


class TestResidualBlock:
    def test_block_matches_reference(self):
        x = rand((1, 8, 16, 16), 8)
        w1 = rand((8, 8, 3, 3), 9)
        w2 = rand((8, 8, 3, 3), 10)
        out = model.tiny_cnn_block(x, w1, w2)
        y = conv2d_nchw_ref(x, w1)
        ref = conv2d_nchw_ref(y, w2) + y
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


class TestArtifactEntrypoints:
    def test_chiplet_matmul_returns_tuple(self):
        a, b = rand((64, 64), 11), rand((64, 64), 12)
        (out,) = model.chiplet_matmul(a, b)
        np.testing.assert_allclose(out, jnp.matmul(a, b), rtol=1e-4,
                                   atol=1e-4)

    def test_chiplet_add_returns_tuple(self):
        a, b = rand((4096,), 13), rand((4096,), 14)
        (out,) = model.chiplet_add(a, b)
        np.testing.assert_allclose(out, a + b, rtol=1e-6, atol=1e-6)

    def test_pad_to(self):
        x = rand((3, 5), 15)
        p = model.pad_to(x, 8, 8)
        assert p.shape == (8, 8)
        np.testing.assert_allclose(p[:3, :5], x)
        assert float(jnp.sum(jnp.abs(p[3:, :]))) == 0.0
