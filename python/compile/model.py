"""L2: the chiplet compute graph in JAX, calling the L1 Pallas kernels.

A WIENNA chiplet executes one sub-layer of the partitioned DNN. Its
compute reduces to (a) GEMM tiles over im2col patches — the NVDLA-like
weight-stationary path used by KP-CP / NP-CP and by FC layers — and
(b) elementwise residual additions. Both are expressed here as jittable
JAX functions whose hot loops are the Pallas kernels; ``aot.py`` lowers
them ONCE to HLO text, and the Rust coordinator executes the artifacts
from its request path. Python never runs at inference time.
"""

import jax
import jax.numpy as jnp

from .kernels.conv_os import conv3x3_os
from .kernels.matmul_ws import add_stream, matmul_ws


def chiplet_matmul(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray]:
    """One GEMM tile on the chiplet PE array (block == tile: a single
    weight-stationary pass). Returns a 1-tuple: artifacts are lowered with
    ``return_tuple=True`` and unwrapped by the Rust runtime."""
    bm, bk = a.shape
    bk2, bn = b.shape
    assert bk == bk2
    return (matmul_ws(a, b, bm=bm, bk=bk, bn=bn, interpret=True),)


def chiplet_add(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Residual addition chunk on the chiplet SIMD lanes."""
    (n,) = a.shape
    return (add_stream(a, b, block=n, interpret=True),)


def chiplet_conv3x3(x: jnp.ndarray, w: jnp.ndarray) -> tuple[jnp.ndarray]:
    """SAME 3x3 stride-1 conv on a Shidiannao-style (output-stationary)
    chiplet — the YP-XP compute path. x: [C, Y, X] unpadded; w: [K, C, 3, 3].
    Lowered per shape by aot.py as ``conv3x3_c{C}k{K}y{Y}``."""
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1)))
    k = w.shape[0]
    kt = 8 if k % 8 == 0 else (4 if k % 4 == 0 else 1)
    return (conv3x3_os(xp, w, kt=kt, interpret=True),)


def pad_to(x: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Zero-pad a 2-D array up to (m, n)."""
    return jnp.pad(x, ((0, m - x.shape[0]), (0, n - x.shape[1])))


def chiplet_conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                   tile: int = 64) -> jnp.ndarray:
    """Full conv2d the way the package computes it: im2col + tiled Pallas
    GEMM with zero-padding to the tile contract. Build-time only — used by
    the L2 tests to prove the tiled lowering matches ``lax.conv``.

    x: [N, C, H, W], w: [K, C, R, S], SAME padding.
    """
    n, c, h, ww = x.shape
    k, _, r, s = w.shape
    ho, wo = -(-h // stride), -(-ww // stride)
    pad_h = max((ho - 1) * stride + r - h, 0)
    pad_w = max((wo - 1) * stride + s - ww, 0)
    xp = jnp.pad(x, ((0, 0), (0, 0),
                     (pad_h // 2, pad_h - pad_h // 2),
                     (pad_w // 2, pad_w - pad_w // 2)))
    cols = []
    for rr in range(r):
        for ss in range(s):
            sl = xp[:, :, rr:rr + stride * ho:stride, ss:ss + stride * wo:stride]
            cols.append(sl.reshape(n, c, ho * wo))
    patches = jnp.stack(cols, axis=2).transpose(0, 3, 1, 2)
    patches = patches.reshape(n * ho * wo, c * r * s)
    wmat = w.reshape(k, c * r * s).T

    m_dim, k_dim = patches.shape
    n_dim = wmat.shape[1]
    mp = -(-m_dim // tile) * tile
    kp = -(-k_dim // tile) * tile
    np_ = -(-n_dim // tile) * tile
    out = matmul_ws(pad_to(patches, mp, kp), pad_to(wmat, kp, np_),
                    bm=tile, bk=tile, bn=tile, interpret=True)
    out = out[:m_dim, :n_dim]
    return out.reshape(n, ho, wo, k).transpose(0, 3, 1, 2)


def tiny_cnn_block(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    """One residual block of the tiny e2e network (conv-conv-add), the
    shape-contract mirror of ``rust/src/workload/tiny.rs``."""
    y = chiplet_conv2d(x, w1)
    z = chiplet_conv2d(y, w2)
    flat_a, flat_b = z.reshape(-1), y.reshape(-1)
    pad = -(-flat_a.shape[0] // 4096) * 4096 - flat_a.shape[0]
    fa = jnp.pad(flat_a, (0, pad))
    fb = jnp.pad(flat_b, (0, pad))
    out = add_stream(fa, fb, block=4096, interpret=True)[:flat_a.shape[0]]
    return out.reshape(z.shape)
