"""L1 Pallas kernel: weight-stationary tiled matmul.

This is the compute hot-spot of a WIENNA chiplet. The paper's chiplets are
NVDLA-like weight-stationary MAC arrays; on a TPU the same insight maps to
(see DESIGN.md §Hardware-Adaptation):

* chiplet local memory  -> VMEM: ``BlockSpec``s stage (patch, filter) tiles
  HBM->VMEM the way WIENNA stages SRAM->chiplet-local-memory;
* the 8x8 PE array      -> the MXU: the inner ``jnp.dot`` contracts a
  (bm, bk) x (bk, bn) tile on the systolic array;
* KP-CP "weights resident, inputs streamed" -> the grid order: the K
  (contraction) dimension is innermost so the output tile accumulates in a
  VMEM scratch register while input tiles stream past — exactly the
  weight-stationary schedule.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path, and real-TPU
efficiency is estimated analytically (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 64


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One grid step: accumulate a (bm, bk) x (bk, bn) product.

    Grid is (m_tiles, n_tiles, k_tiles) with k innermost ("arbitrary"
    semantics): the output tile block index is constant across the k steps
    of one (m, n) tile, so ``o_ref`` stays resident in VMEM and serves as
    the f32 accumulator — the weight-stationary accumulation.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def matmul_ws(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = DEFAULT_BLOCK,
              bk: int = DEFAULT_BLOCK, bn: int = DEFAULT_BLOCK,
              interpret: bool = True) -> jnp.ndarray:
    """Tiled matmul ``a[m,k] @ b[k,n]`` with a weight-stationary schedule.

    Shapes must be multiples of the block sizes (the AOT wrapper pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"shape ({m},{k})x({k},{n}) not a multiple of blocks ({bm},{bk},{bn})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def add_stream(a: jnp.ndarray, b: jnp.ndarray, *, block: int = 4096,
               interpret: bool = True) -> jnp.ndarray:
    """Elementwise residual addition, streamed through VMEM in `block`
    chunks (the collection-side reuse of the chiplet SIMD lanes)."""
    (n,) = a.shape
    assert a.shape == b.shape
    assert n % block == 0, f"length {n} not a multiple of {block}"
    return pl.pallas_call(
        _add_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(a, b)


def vmem_footprint_bytes(bm: int, bk: int, bn: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step: an A tile, a B tile,
    the output tile and the f32 accumulator (double-buffered inputs)."""
    a = bm * bk * dtype_bytes * 2   # double buffer
    b = bk * bn * dtype_bytes * 2
    o = bm * bn * dtype_bytes
    acc = bm * bn * 4
    return a + b + o + acc


def mxu_utilization_estimate(bm: int, bk: int, bn: int,
                             mxu: int = 128) -> float:
    """Fraction of MXU lanes a (bm,bk)x(bk,bn) tile keeps busy: each MXU
    pass contracts a (mxu, mxu) tile, so utilization is the product of the
    per-dimension fill ratios."""
    fill = lambda d: d / (mxu * -(-d // mxu))
    return fill(bm) * fill(bk) * fill(bn)
