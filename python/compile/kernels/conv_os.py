"""L1 Pallas kernel: output-stationary direct 3x3 convolution.

The paper pairs the YP-XP (activation-partitioned) strategy with
Shidiannao-like chiplets (Table 4): the PE array is spatially mapped onto
the *output plane* and partial sums stay resident while inputs shift
past. On TPU the same insight becomes an output-stationary Pallas kernel:

* the output tile `[Kt, Y, X]` is the resident VMEM block (the Shidiannao
  PE-array state);
* the input halo window `[C, Y+2, X+2]` is staged once per grid step and
  *shifted* nine times (the `x[:, rr:rr+Y, ss:ss+X]` slices) — exactly the
  neighbour-shifting ShiDianNao performs with its inter-PE links;
* the filter-bank contraction per shift is a `[Kt, C] x [C, Y*X]` matmul
  on the MXU.

Stride-1, 3x3, SAME padding — the shape class the YP-XP chiplets execute
in the end-to-end demo. interpret=True for CPU-PJRT (see matmul_ws.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv3x3_kernel(x_ref, w_ref, o_ref, *, y: int, x: int):
    """One grid step: one output-channel tile, full output plane resident.

    x_ref: [C, Y+2, X+2] (SAME-padded input), w_ref: [Kt, C, 3, 3],
    o_ref: [Kt, Y, X].
    """
    xin = x_ref[...]
    w = w_ref[...]
    kt = w.shape[0]
    acc = jnp.zeros((kt, y, x), jnp.float32)
    # Nine shifted contractions — the ShiDianNao systolic shift pattern.
    for rr in range(3):
        for ss in range(3):
            patch = xin[:, rr:rr + y, ss:ss + x]          # [C, Y, X]
            tap = w[:, :, rr, ss]                          # [Kt, C]
            acc = acc + jnp.einsum(
                "kc,cyx->kyx", tap, patch,
                preferred_element_type=jnp.float32)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("kt", "interpret"))
def conv3x3_os(xp: jnp.ndarray, w: jnp.ndarray, *, kt: int = 8,
               interpret: bool = True) -> jnp.ndarray:
    """Output-stationary SAME 3x3 conv.

    xp: [C, Y+2, X+2] pre-padded input; w: [K, C, 3, 3]; returns [K, Y, X].
    `kt` is the output-channel tile (grid dimension).
    """
    c, yp_, xp_ = xp.shape
    k = w.shape[0]
    y, x = yp_ - 2, xp_ - 2
    assert w.shape == (k, c, 3, 3)
    assert k % kt == 0, f"K={k} not a multiple of kt={kt}"
    return pl.pallas_call(
        functools.partial(_conv3x3_kernel, y=y, x=x),
        grid=(k // kt,),
        in_specs=[
            # Full input halo window resident each step.
            pl.BlockSpec((c, yp_, xp_), lambda i: (0, 0, 0)),
            pl.BlockSpec((kt, c, 3, 3), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((kt, y, x), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, y, x), jnp.float32),
        interpret=interpret,
    )(xp, w)


def vmem_footprint_bytes(c: int, y: int, x: int, kt: int,
                         dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid step: the padded input window, one
    filter tile and the resident output tile."""
    xin = c * (y + 2) * (x + 2) * dtype_bytes
    w = kt * c * 9 * dtype_bytes
    out = kt * y * x * 4
    return xin + w + out
