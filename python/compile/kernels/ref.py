"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package is validated against the corresponding function here (pytest +
hypothesis sweeps in ``python/tests/``), and the Rust end-to-end path is
in turn validated against an independent naive convolution oracle.
"""

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul in f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def add_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise addition (residual/skip connections)."""
    return a + b


def conv2d_nchw_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                    padding: str = "SAME") -> jnp.ndarray:
    """Reference NCHW conv2d via lax, used by the L2 model tests."""
    import jax.lax as lax
    return lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def im2col_matmul_conv_ref(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1,
                           padding: str = "SAME") -> jnp.ndarray:
    """Conv2d lowered the way the WIENNA chiplet computes it: im2col
    patches x filter matrix. Used to check that the GEMM lowering is
    numerically identical to the direct convolution."""
    n, c, h, ww = x.shape
    k, _, r, s = w.shape
    if padding == "SAME":
        ho, wo = -(-h // stride), -(-ww // stride)
        pad_h = max((ho - 1) * stride + r - h, 0)
        pad_w = max((wo - 1) * stride + s - ww, 0)
        x = jnp.pad(x, ((0, 0), (0, 0),
                        (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2)))
    else:
        ho, wo = (h - r) // stride + 1, (ww - s) // stride + 1
    # Gather patches -> [n*ho*wo, c*r*s]
    cols = []
    for rr in range(r):
        for ss in range(s):
            sl = x[:, :, rr:rr + stride * ho:stride, ss:ss + stride * wo:stride]
            cols.append(sl.reshape(n, c, ho * wo))
    patches = jnp.stack(cols, axis=2)          # [n, c, r*s, ho*wo]
    patches = patches.transpose(0, 3, 1, 2)    # [n, ho*wo, c, r*s]
    patches = patches.reshape(n * ho * wo, c * r * s)
    wmat = w.reshape(k, c * r * s).T           # [c*r*s, k]
    out = matmul_ref(patches, wmat)            # [n*ho*wo, k]
    return out.reshape(n, ho, wo, k).transpose(0, 3, 1, 2)
